"""Render results/dryrun.jsonl into the EXPERIMENTS.md roofline table."""
import json
from collections import OrderedDict


def fmt_bytes(b):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= div:
            return f"{b/div:.1f}{unit}"
    return f"{b:.0f}B"


def main(path="results/dryrun.jsonl", mesh_filter=None, variants=False):
    rows = OrderedDict()
    for line in open(path):
        r = json.loads(line)
        is_variant = (r.get("mix_mode", "dense") != "dense" or r.get("psi", 0) != 0
                      or r.get("mix_dtype", "f32") != "f32"
                      or r.get("blocked_threshold", 8192) != 8192
                      or r.get("cache_shard", "kv_heads") != "kv_heads")
        if is_variant != variants:
            continue
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        key = (r["arch"], r["shape"], r["mesh"], r.get("mix_mode"), r.get("psi"),
               r.get("mix_dtype"), r.get("blocked_threshold"))
        rows[key] = r  # last write wins

    print("| arch | shape | mesh | mode | t_comp | t_mem | t_coll | bound | "
          "MODEL_FLOPs | useful | temp/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows.values():
        temp = r["memory_analysis"].get("temp_size_in_bytes") or 0
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['mode']} | "
              f"{r['t_compute_s']*1e3:.1f}ms | {r['t_memory_s']*1e3:.1f}ms | "
              f"{r['t_collective_s']*1e3:.1f}ms | {r['bottleneck']} | "
              f"{r['model_flops']:.2e} | {r['useful_flops_ratio']:.2f} | "
              f"{fmt_bytes(temp)} |")
    print(f"\n{len(rows)} rows")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="results/dryrun.jsonl")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--variants", action="store_true")
    a = ap.parse_args()
    main(a.path, a.mesh, a.variants)
