import jax, jax.numpy as jnp
from repro.configs.base import ARCH_IDS, get_reduced
from repro.models.registry import build_model

key = jax.random.PRNGKey(0)
for arch in ARCH_IDS:
    cfg = get_reduced(arch)
    m = build_model(cfg)
    params = m.init(key)
    B, S = 2, 64
    batch = {}
    if cfg.embeds_in:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["cross_embeds"] = jax.random.normal(key, (B, cfg.num_patch_tokens, cfg.d_model))
    logits, aux = m.apply(params, batch)
    loss = m.loss(params, batch)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    assert logits.shape == (B, S, cfg.vocab_size), (arch, logits.shape)
    assert jnp.isfinite(logits).all(), arch
    # decode
    st = m.init_decode_state(B, 32)
    cross_kv = None
    if cfg.family == "vlm":
        cross_kv = m.init_cross_kv(params, batch["cross_embeds"])
    tok = jnp.zeros((B,), jnp.int32) if not cfg.embeds_in else jax.random.normal(key, (B, 1, cfg.d_model))
    lg, st2 = m.decode_step(params, tok, st, cross_kv)
    assert lg.shape == (B, cfg.vocab_size) and jnp.isfinite(lg).all(), arch
    print(f"OK {arch:24s} loss={float(loss):.3f} params={n_params}")
print("ALL MODELS OK")
