#!/usr/bin/env python
"""Run the full lint stack locally: repro.analysis, then ruff (if
installed — ruff is a dev dependency, see requirements-dev.txt).

    python scripts/lint.py            # analyzer + ruff, human output
    python scripts/lint.py --strict   # what CI runs (warnings fail)

Extra args are forwarded to `python -m repro.analysis`.
"""
from __future__ import annotations

import os
import shutil
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH")) if p)

    analysis = subprocess.call(
        [sys.executable, "-m", "repro.analysis", "src", "tests",
         *sys.argv[1:]], cwd=REPO, env=env)

    ruff = 0
    if shutil.which("ruff"):
        ruff = subprocess.call(["ruff", "check", "."], cwd=REPO)
    else:
        print("ruff not installed; skipping the generic-Python layer "
              "(pip install -r requirements-dev.txt)", file=sys.stderr)

    return analysis or ruff


if __name__ == "__main__":
    sys.exit(main())
