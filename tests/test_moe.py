
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_reduced
from repro.models.moe import _capacity, init_moe, moe_block


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("olmoe-1b-7b").with_(capacity_factor=8.0)  # ample capacity
    key = jax.random.PRNGKey(0)
    params = init_moe(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    return cfg, params, x


def _dense_reference(params, x, cfg):
    """Weighted sum over top-k experts, computed densely per token."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / gate.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.num_experts):
        h = jax.nn.silu(xt @ params["experts_gate"][e]) * (xt @ params["experts_up"][e])
        outs.append(h @ params["experts_down"][e])
    outs = jnp.stack(outs, axis=1)  # (T, E, d)
    w = jnp.zeros((xt.shape[0], cfg.num_experts))
    for k in range(cfg.experts_per_token):
        w = w.at[jnp.arange(xt.shape[0]), eidx[:, k]].add(gate[:, k])
    return jnp.einsum("te,ted->td", w, outs).reshape(B, S, d)


def test_moe_matches_dense_reference(setup):
    cfg, params, x = setup
    out, aux = moe_block(params, x, cfg)
    ref = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-3)
    assert float(aux) > 0


def test_capacity_drop(setup):
    """With capacity ~0 most tokens are dropped -> output ~ 0."""
    cfg, params, x = setup
    tiny = cfg.with_(capacity_factor=1e-6)
    out, _ = moe_block(params, x, tiny)
    full, _ = moe_block(params, x, cfg)
    assert float(jnp.abs(out).mean()) < float(jnp.abs(full).mean())


def test_capacity_rounding():
    cfg = get_reduced("olmoe-1b-7b")
    c = _capacity(1024, cfg)
    assert c % 8 == 0 and c >= cfg.capacity_factor * cfg.experts_per_token * 1024 / cfg.num_experts - 8


def test_aux_loss_uniform_router(setup):
    """Uniform routing -> aux == E * sum(1/E * 1/E) * w = weight."""
    cfg, params, x = setup
    p2 = dict(params)
    p2["router"] = jnp.zeros_like(params["router"])
    _, aux = moe_block(p2, x, cfg)
    np.testing.assert_allclose(float(aux), cfg.router_aux_weight, rtol=1e-2)


def test_moe_grads_finite(setup):
    cfg, params, x = setup

    def loss(p):
        out, aux = moe_block(p, x, cfg)
        return (out.astype(jnp.float32) ** 2).mean() + aux

    g = jax.grad(loss)(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.isfinite(leaf).all())
