"""DRACO protocol behaviour tests (the paper's Algorithm 1/2 invariants)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import ChannelConfig
from repro.core.protocol import (
    DracoConfig,
    build_graph,
    draco_window,
    init_state,
    run_windows,
    virtual_global_model,
)
from repro.data.synthetic import federated_classification, make_mlp

N = 6


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    train, test = federated_classification(k1, N, input_dim=8, num_classes=4,
                                           per_client=128)
    params0, apply, loss, acc = make_mlp(k2, 8, (16,), 4)
    return train, test, params0, loss, acc


def _cfg(**kw):
    base = dict(num_clients=N, lr=0.1, local_batches=1, batch_size=16,
                lambda_grad=0.8, lambda_tx=0.8, unify_period=0, psi=0,
                topology="complete", max_delay_windows=3, channel=None)
    base.update(kw)
    return DracoConfig(**base)


def test_draco_learns(task):
    train, test, params0, loss, acc = task
    cfg = _cfg(unify_period=25)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(1), cfg, params0)
    tx_, ty_ = test
    acc0 = float(jax.vmap(lambda p: acc(p, tx_, ty_))(st.params).mean())
    st = run_windows(st, cfg, q, adj, loss, train, 250)
    acc1 = float(jax.vmap(lambda p: acc(p, tx_, ty_))(st.params).mean())
    assert acc1 > acc0 + 0.15, (acc0, acc1)
    for leaf in jax.tree_util.tree_leaves(st.params):
        assert bool(jnp.isfinite(leaf).all())


def test_unification_equalizes(task):
    train, _, params0, loss, _ = task
    cfg = _cfg(unify_period=10)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(2), cfg, params0)
    st = run_windows(st, cfg, q, adj, loss, train, 10)  # exactly one unification
    for leaf in jax.tree_util.tree_leaves(st.params):
        spread = jnp.abs(leaf - leaf[0:1]).max()
        assert float(spread) == 0.0
    assert int(st.accept_count.max()) == 0  # reset at unification


def test_no_tx_no_param_change(task):
    train, _, params0, loss, _ = task
    cfg = _cfg(lambda_tx=0.0, unify_period=0)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(3), cfg, params0)
    st2 = run_windows(st, cfg, q, adj, loss, train, 20)
    # nothing transmitted -> reference models never renewed (paper: senders
    # do not apply their own updates)
    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # but pending backlogs accumulated
    pend = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(st2.pending))
    assert pend > 0


def test_psi_cap_respected(task):
    train, _, params0, loss, _ = task
    psi = 2
    cfg = _cfg(psi=psi, unify_period=50, lambda_tx=5.0, lambda_grad=5.0)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(4), cfg, params0)
    for _ in range(49):  # stay within one unification period
        st = draco_window(st, cfg, q, adj, loss, train)
    assert int(st.accept_count.max()) <= psi


def test_self_update_off_by_default(task):
    """Algorithm 1: local training only produces Delta; x^(i) changes only
    via reception. With delays >= 1 window, params after one window with
    guaranteed grad events but no arrivals are unchanged."""
    train, _, params0, loss, _ = task
    cfg = _cfg(lambda_grad=100.0, lambda_tx=0.0)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(5), cfg, params0)
    st2 = draco_window(st, cfg, q, adj, loss, train)
    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_delayed_delivery(task):
    """A transmission enqueued in window k arrives in a later window."""
    train, _, params0, loss, _ = task
    cfg = _cfg(lambda_grad=100.0, lambda_tx=100.0, max_delay_windows=4)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(6), cfg, params0)
    st1 = draco_window(st, cfg, q, adj, loss, train)
    # params unchanged after window 1 (messages in flight)...
    changed1 = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(st.params),
                        jax.tree_util.tree_leaves(st1.params)))
    assert not changed1
    st2 = draco_window(st1, cfg, q, adj, loss, train)
    changed2 = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(st1.params),
                        jax.tree_util.tree_leaves(st2.params)))
    assert changed2  # ...and land in window 2 (delay = 1 window default)


def test_wireless_channel_path(task):
    train, test, params0, loss, acc = task
    cfg = _cfg(unify_period=25,
               channel=ChannelConfig(message_bytes=51_640, gamma_max=10.0))
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(7), cfg, params0)
    st = run_windows(st, cfg, q, adj, loss, train, 150)
    tx_, ty_ = test
    a = float(jax.vmap(lambda p: acc(p, tx_, ty_))(st.params).mean())
    assert a > 0.3
    for leaf in jax.tree_util.tree_leaves(st.params):
        assert bool(jnp.isfinite(leaf).all())


def test_virtual_global_model(task):
    _, _, params0, _, _ = task
    cfg = _cfg()
    st = init_state(jax.random.PRNGKey(8), cfg, params0)
    vg = virtual_global_model(st.params)
    for l0, lv in zip(jax.tree_util.tree_leaves(params0),
                      jax.tree_util.tree_leaves(vg)):
        np.testing.assert_allclose(np.asarray(l0), np.asarray(lv), atol=1e-6)
