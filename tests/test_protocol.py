"""DRACO protocol behaviour tests (the paper's Algorithm 1/2 invariants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import ChannelConfig
from repro.core.protocol import (
    DracoConfig,
    build_graph,
    draco_window,
    init_state,
    run_windows,
    virtual_global_model,
)
from repro.data.synthetic import federated_classification, make_mlp

N = 6


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    train, test = federated_classification(k1, N, input_dim=8, num_classes=4,
                                           per_client=128)
    params0, apply, loss, acc = make_mlp(k2, 8, (16,), 4)
    return train, test, params0, loss, acc


def _cfg(**kw):
    base = dict(num_clients=N, lr=0.1, local_batches=1, batch_size=16,
                lambda_grad=0.8, lambda_tx=0.8, unify_period=0, psi=0,
                topology="complete", max_delay_windows=3, channel=None)
    base.update(kw)
    return DracoConfig(**base)


def test_draco_learns(task):
    train, test, params0, loss, acc = task
    cfg = _cfg(unify_period=25)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(1), cfg, params0)
    tx_, ty_ = test
    acc0 = float(jax.vmap(lambda p: acc(p, tx_, ty_))(st.params).mean())
    st = run_windows(st, cfg, q, adj, loss, train, 250)
    acc1 = float(jax.vmap(lambda p: acc(p, tx_, ty_))(st.params).mean())
    assert acc1 > acc0 + 0.15, (acc0, acc1)
    for leaf in jax.tree_util.tree_leaves(st.params):
        assert bool(jnp.isfinite(leaf).all())


def test_unification_equalizes(task):
    train, _, params0, loss, _ = task
    cfg = _cfg(unify_period=10)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(2), cfg, params0)
    st = run_windows(st, cfg, q, adj, loss, train, 10)  # exactly one unification
    for leaf in jax.tree_util.tree_leaves(st.params):
        spread = jnp.abs(leaf - leaf[0:1]).max()
        assert float(spread) == 0.0
    assert int(st.accept_count.max()) == 0  # reset at unification


def test_no_tx_no_param_change(task):
    train, _, params0, loss, _ = task
    cfg = _cfg(lambda_tx=0.0, unify_period=0)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(3), cfg, params0)
    st2 = run_windows(st, cfg, q, adj, loss, train, 20)
    # nothing transmitted -> reference models never renewed (paper: senders
    # do not apply their own updates)
    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # but pending backlogs accumulated
    pend = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(st2.pending))
    assert pend > 0


@pytest.mark.slow  # ~37s: the single heaviest protocol battery
def test_psi_cap_respected(task):
    train, _, params0, loss, _ = task
    psi = 2
    cfg = _cfg(psi=psi, unify_period=50, lambda_tx=5.0, lambda_grad=5.0)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(4), cfg, params0)
    for _ in range(49):  # stay within one unification period
        st = draco_window(st, cfg, q, adj, loss, train)
    assert int(st.accept_count.max()) <= psi


def test_self_update_off_by_default(task):
    """Algorithm 1: local training only produces Delta; x^(i) changes only
    via reception. With delays >= 1 window, params after one window with
    guaranteed grad events but no arrivals are unchanged."""
    train, _, params0, loss, _ = task
    cfg = _cfg(lambda_grad=100.0, lambda_tx=0.0)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(5), cfg, params0)
    st2 = draco_window(st, cfg, q, adj, loss, train)
    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(st2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_delayed_delivery(task):
    """A transmission enqueued in window k arrives in a later window."""
    train, _, params0, loss, _ = task
    cfg = _cfg(lambda_grad=100.0, lambda_tx=100.0, max_delay_windows=4)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(6), cfg, params0)
    st1 = draco_window(st, cfg, q, adj, loss, train)
    # params unchanged after window 1 (messages in flight)...
    changed1 = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(st.params),
                        jax.tree_util.tree_leaves(st1.params)))
    assert not changed1
    st2 = draco_window(st1, cfg, q, adj, loss, train)
    changed2 = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree_util.tree_leaves(st1.params),
                        jax.tree_util.tree_leaves(st2.params)))
    assert changed2  # ...and land in window 2 (delay = 1 window default)


def test_wireless_channel_path(task):
    train, test, params0, loss, acc = task
    cfg = _cfg(unify_period=25,
               channel=ChannelConfig(message_bytes=51_640, gamma_max=10.0))
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(7), cfg, params0)
    st = run_windows(st, cfg, q, adj, loss, train, 150)
    tx_, ty_ = test
    a = float(jax.vmap(lambda p: acc(p, tx_, ty_))(st.params).mean())
    assert a > 0.3
    for leaf in jax.tree_util.tree_leaves(st.params):
        assert bool(jnp.isfinite(leaf).all())


def test_virtual_global_model(task):
    _, _, params0, _, _ = task
    cfg = _cfg()
    st = init_state(jax.random.PRNGKey(8), cfg, params0)
    vg = virtual_global_model(st.params)
    for l0, lv in zip(jax.tree_util.tree_leaves(params0),
                      jax.tree_util.tree_leaves(vg)):
        np.testing.assert_allclose(np.asarray(l0), np.asarray(lv), atol=1e-6)


# ---------------------------------------------------------------------------
# Config validation (PR 4): degenerate knobs fail loudly at construction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw,msg", [
    (dict(num_clients=0), "num_clients"),
    (dict(num_clients=-3), "num_clients"),
    (dict(window=0.0), "window"),
    (dict(window=-1.0), "window"),
    (dict(max_delay_windows=1), "max_delay_windows"),
    (dict(psi=-1), "psi"),
    (dict(unify_period=-5), "unify_period"),
])
def test_config_validation_rejects(kw, msg):
    with pytest.raises(ValueError, match=msg):
        _cfg(**kw)


def test_config_validation_accepts_boundaries():
    _cfg(max_delay_windows=2, psi=0, unify_period=0)  # all legal minima


# ---------------------------------------------------------------------------
# Over-delay delivery bugfix (PR 4): a link whose true delay spans >= D
# windows is DROPPED (channel-outage semantics), never delivered early
# at age D-1. The exact boundary gamma = (D-1)*window stays deliverable.
# ---------------------------------------------------------------------------


def test_quantize_delays_boundary():
    from repro.core.protocol import quantize_delays

    D, w = 4, 0.5
    gamma = jnp.array([[0.01, (D - 1) * w],         # 1 window | exact boundary
                       [(D - 1) * w + 1e-4, 10.0]])  # just past | way past
    delay_w, deliverable = quantize_delays(gamma, w, D)
    np.testing.assert_array_equal(np.asarray(delay_w),
                                  [[1, D - 1], [D - 1, D - 1]])
    np.testing.assert_array_equal(np.asarray(deliverable),
                                  [[True, True], [False, False]])


def _fixed_channel_state_and_cfg(gamma_rows, window=1.0, D=4):
    """A protocol state + cfg whose channel draws are pinned to
    `gamma_rows` (monkeypatched transmission_delays)."""
    cfg = _cfg(window=window, max_delay_windows=D, lambda_tx=1e9,
               channel=ChannelConfig(gamma_max=1e9))
    return cfg


def test_over_delay_links_are_dropped(task, monkeypatch):
    """w_eff zeros exactly the links whose quantized delay >= D, in both
    the fused `_tx_and_accept` and the legacy engine's inline path."""
    from repro.core import channel as channel_lib
    from repro.core import protocol as protocol_lib

    train, _, params0, loss, _ = task
    D, w = 4, 1.0
    n = N
    cfg = _fixed_channel_state_and_cfg(None, window=w, D=D)
    # pinned per-link delays: row 0 at the exact (D-1)*w boundary
    # (deliverable), row 1 just past it (dropped), everything else fast
    gamma = np.full((n, n), 0.5, np.float64)
    gamma[0, :] = (D - 1) * w
    gamma[1, :] = (D - 1) * w + 1e-3

    def fixed_delays(key, pos, tx_mask, chan_cfg):
        g = jnp.asarray(gamma, jnp.float32)
        return g, (g <= chan_cfg.gamma_max) & tx_mask[:, None]

    monkeypatch.setattr(channel_lib, "transmission_delays", fixed_delays)

    q, adj = build_graph(cfg)
    key = jax.random.PRNGKey(0)
    st = init_state(key, cfg, params0)
    keys = jax.random.split(st.key, 8)
    tx_mask, w_eff, delay_w, _, _ = protocol_lib._tx_and_accept(
        st, cfg, q, adj, keys[3], keys[4], keys[5])
    assert bool(tx_mask.all())  # lambda_tx huge: everyone transmits
    w_eff = np.asarray(w_eff)
    adj_np = np.asarray(adj)
    # boundary row delivered at max age, over-delay row fully dropped
    assert (w_eff[0][adj_np[0]] > 0).all()
    np.testing.assert_array_equal(w_eff[1], np.zeros((n,)))
    assert (np.asarray(delay_w)[0][adj_np[0]] == D - 1).all()

    # legacy engine drops the same links: its buffer never receives
    # payload mass from sender 1
    st_l = protocol_lib.init_state_legacy(key, cfg, params0)
    st_l2 = protocol_lib.draco_window_legacy(st_l, cfg, q, adj, loss, train)
    st_f2 = protocol_lib.draco_window(st, cfg, q, adj, loss, train)
    flat_legacy = np.concatenate(
        [np.asarray(b).reshape(D, n, -1)
         for b in jax.tree_util.tree_leaves(st_l2.buffer)], axis=-1)
    # fused ring stores raw payloads; mix them per-slot to compare the
    # delivered mass with the legacy pre-mixed buffer
    for age in range(1, D):
        slot = age % D  # widx=0: messages of delay d land in slot d
        w_age = np.asarray(st_f2.w_ring[0]) * (
            np.asarray(st_f2.delay_ring[0]) == age)
        mixed = w_age.T @ np.asarray(st_f2.buffer[0])
        np.testing.assert_allclose(flat_legacy[slot], mixed, atol=1e-6)


def test_event_timeline_cross_view_bitwise(task):
    """Cross-view: the exact `event_list` timeline replayed message-by-
    message (`repro.events.replay`) equals the jit-scanned tape engine
    bit-for-bit — params, Psi counters, broadcast counts — and the
    tape's unification rows follow the same rotating-hub rule as the
    window engine (`unify_hub`)."""
    from repro.core.events import unify_hub
    from repro.events import (
        KIND_UNIFY,
        events_context,
        init_event_state,
        replay_events,
        simulate_events,
    )

    train, _, params0, loss, _ = task
    cfg = _cfg(unify_period=6, psi=1, lambda_grad=0.5, lambda_tx=0.5)
    ctx = events_context(cfg, loss, train, params0=params0, horizon=12.0)
    key = jax.random.PRNGKey(5)
    st, _ = simulate_events("draco-event", cfg, params0=params0, ctx=ctx,
                            key=key)
    rp = replay_events(init_event_state(key, cfg, params0), ctx)
    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(rp.params)):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert (np.asarray(st.pending) == np.asarray(rp.pending)).all()
    assert (np.asarray(st.accept_count) == np.asarray(rp.accept_count)).all()
    assert (np.asarray(st.total_accept) == np.asarray(rp.total_accept)).all()
    assert (np.asarray(st.tx_sent) == np.asarray(rp.tx_sent)).all()
    assert int(st.tx_count) == rp.tx_count
    kinds = np.asarray(ctx.tape.kind)[np.asarray(ctx.tape.valid)]
    hubs = np.asarray(ctx.tape.client)[np.asarray(ctx.tape.valid)]
    hubs = hubs[kinds == KIND_UNIFY].tolist()
    assert hubs == [unify_hub(k, N) for k in range(1, len(hubs) + 1)]
