"""Batched delay-bucketed gossip kernels (enqueue/drain) vs oracles.

Edge cases the fused engine depends on: client counts off the 8-sublane
grid, block_d padding remainders, bf16 payloads with f32 accumulation,
empty-bucket skipping, and parity with the batched-einsum reference
across ring depths D in {2, 4, 8}.  (Kept hypothesis-free so the suite
runs even where tests/test_kernels_gossip.py is skipped.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gossip.ops import gossip_drain, gossip_enqueue
from repro.kernels.gossip.ref import (
    gossip_drain_ref,
    gossip_enqueue_ref,
    gossip_mix_ref,
)


def _bucketed_weights(key, n, num_buckets):
    """(J, N, N) masked weights: a row-stochastic Q split by a random
    per-link delay bucket (the DRACO enqueue structure: each edge lands
    in exactly one bucket)."""
    kq, kd = jax.random.split(key)
    q = jax.nn.softmax(jax.random.normal(kq, (n, n)), axis=1)
    delay = jax.random.randint(kd, (n, n), 1, num_buckets + 1)
    buckets = jnp.arange(1, num_buckets + 1)
    return q[None] * (delay[None] == buckets[:, None, None]).astype(jnp.float32)


@pytest.mark.parametrize("D", [2, 4, 8])
def test_enqueue_kernel_matches_batched_einsum(D):
    """Pallas enqueue == the batched-einsum reference across ring depths."""
    n, k = 16, 256
    key = jax.random.PRNGKey(D)
    w_stack = _bucketed_weights(key, n, D - 1)
    pending = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    out = gossip_enqueue(w_stack, pending, use_kernel=True, interpret=True,
                         block_d=128)
    ref = gossip_enqueue_ref(w_stack, pending)
    assert out.shape == (D - 1, n, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_enqueue_n_not_multiple_of_8():
    """Client counts off the sublane grid (25, 7) round-trip through the
    zero-padding without polluting real rows."""
    for n in (25, 7):
        key = jax.random.PRNGKey(n)
        w_stack = _bucketed_weights(key, n, 3)
        pending = jax.random.normal(jax.random.fold_in(key, 1), (n, 192))
        out = gossip_enqueue(w_stack, pending, use_kernel=True, interpret=True,
                             block_d=64)
        ref = gossip_enqueue_ref(w_stack, pending)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_enqueue_block_d_padding_remainder():
    """K that leaves a block_d remainder (513 % 128 != 0) is padded and
    sliced back exactly."""
    n, k = 8, 513
    key = jax.random.PRNGKey(0)
    w_stack = _bucketed_weights(key, n, 3)
    pending = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    out = gossip_enqueue(w_stack, pending, use_kernel=True, interpret=True,
                         block_d=128)
    assert out.shape == (3, n, k)
    ref = gossip_enqueue_ref(w_stack, pending)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_enqueue_bf16_deltas_f32_accumulation():
    """bf16 payloads accumulate in f32 inside the kernel; requesting an
    f32 output must match the f32-accumulated reference to f32-rounding
    precision (not bf16 precision)."""
    n, k = 16, 256
    key = jax.random.PRNGKey(3)
    w_stack = _bucketed_weights(key, n, 3)
    pending = jax.random.normal(jax.random.fold_in(key, 1), (n, k)).astype(
        jnp.bfloat16)
    out = gossip_enqueue(w_stack, pending, use_kernel=True, interpret=True,
                         block_d=128, out_dtype=jnp.float32)
    assert out.dtype == jnp.float32
    ref = gossip_enqueue_ref(w_stack, pending, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-6, rtol=1e-6)
    # default output dtype follows the payload dtype
    out_bf = gossip_enqueue(w_stack, pending, use_kernel=True, interpret=True,
                            block_d=128)
    assert out_bf.dtype == jnp.bfloat16


@pytest.mark.parametrize("D", [2, 4, 8])
def test_drain_kernel_matches_reference(D):
    """Pallas fused drain == einsum oracle, via ring + chronological slots."""
    n, k, S = 12, 200, D
    key = jax.random.PRNGKey(20 + D)
    w_stack = _bucketed_weights(key, n, D - 1)
    ring = jax.random.normal(jax.random.fold_in(key, 1), (S, n, k))
    slots = jnp.arange(D - 1, dtype=jnp.int32)
    out = gossip_drain(w_stack, ring, slots, use_kernel=True, interpret=True,
                       block_d=64)
    ref = gossip_drain_ref(w_stack, ring[slots])
    assert out.shape == (n, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_drain_fallback_matches_reference_and_skips_empty_buckets():
    """The XLA fallback (unrolled GEMMs + lax.cond bucket skipping) equals
    the oracle, including when some buckets carry no edges at all."""
    n, k, J = 9, 130, 5
    key = jax.random.PRNGKey(7)
    w_stack = _bucketed_weights(key, n, J)
    w_stack = w_stack.at[1].set(0.0).at[3].set(0.0)  # empty buckets
    ring = jax.random.normal(jax.random.fold_in(key, 1), (J + 2, n, k))
    slots = jnp.asarray([6, 2, 5, 0, 3], jnp.int32)
    out = gossip_drain(w_stack, ring, slots, use_kernel=False)
    ref = gossip_drain_ref(w_stack, ring[slots])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    # all-empty drain is exactly zero
    zero = gossip_drain(jnp.zeros_like(w_stack), ring, slots, use_kernel=False)
    assert not np.asarray(zero).any()


def test_enqueue_buckets_sum_to_full_mix():
    """Buckets partition the edge set, so summing the bucketed outputs
    recovers the unbucketed gossip mix (linearity of the engine)."""
    n, k = 10, 96
    key = jax.random.PRNGKey(42)
    w_stack = _bucketed_weights(key, n, 4)
    pending = jax.random.normal(jax.random.fold_in(key, 1), (n, k))
    out = gossip_enqueue(w_stack, pending, use_kernel=True, interpret=True,
                         block_d=32)
    full = gossip_mix_ref(w_stack.sum(0), pending)
    np.testing.assert_allclose(np.asarray(out.sum(0)), np.asarray(full),
                               atol=1e-4, rtol=1e-4)


def test_drain_rectangular_weights_both_paths():
    """A client shard drains its senders slice against ALL receivers:
    w (J, N_loc, M) rectangular (the `gossip_drain_sharded` per-device
    shape). Both the Pallas path and the XLA fallback must return the
    full (M, K) aggregate — the kernel path used to assume square
    weights and silently truncated to (N_loc, K)."""
    key = jax.random.PRNGKey(11)
    J, S, n_loc, m, k = 3, 4, 8, 16, 37
    w = jax.random.normal(key, (J, n_loc, m))
    ring = jax.random.normal(jax.random.fold_in(key, 1), (S, n_loc, k))
    slots = jnp.array([1, 3, 0])
    ref = np.zeros((m, k), np.float32)
    for j, s in enumerate([1, 3, 0]):
        ref = ref + np.asarray(w[j]).T @ np.asarray(ring[s])
    fallback = gossip_drain(w, ring, slots, use_kernel=False)
    kernel = gossip_drain(w, ring, slots, use_kernel=True, interpret=True)
    assert fallback.shape == kernel.shape == (m, k)
    np.testing.assert_allclose(np.asarray(fallback), ref, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(kernel), ref, atol=1e-5, rtol=1e-5)
