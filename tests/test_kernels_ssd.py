"""SSD Pallas kernel vs sequential-recurrence oracle: sweeps + decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd.ops import ssd_forward_kernel
from repro.kernels.ssd.ref import ssd_chunk_ref
from repro.kernels.ssd.ssd import ssd_chunk_pallas
from repro.models.ssm import ssd_chunked, ssd_reference

# tier-2: SSD kernel battery (~30s) (ROADMAP tier-1 runs -m "not slow")
pytestmark = pytest.mark.slow

CASES = [
    # (B, T, H, P, G, N, chunk)
    (2, 64, 4, 8, 2, 16, 16),
    (1, 128, 8, 16, 1, 32, 32),
    (2, 96, 6, 8, 3, 8, 32),
    (1, 32, 2, 4, 1, 4, 8),
]


def _inputs(case, seed=0, dtype=jnp.float32):
    B, T, H, P, G, N, Q = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, T, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B_ = jax.random.normal(ks[3], (B, T, G, N)).astype(dtype)
    C_ = jax.random.normal(ks[4], (B, T, G, N)).astype(dtype)
    D = jnp.ones((H,))
    return x, dt, A, B_, C_, D, Q


@pytest.mark.parametrize("case", CASES)
def test_kernel_vs_oracle(case):
    x, dt, A, B_, C_, D, Q = _inputs(case)
    ref = ssd_reference(x, dt, A, B_, C_, D)
    out = ssd_forward_kernel(x, dt, A, B_, C_, D, chunk=Q, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("case", CASES[:2])
def test_kernel_vs_oracle_bf16(case):
    x, dt, A, B_, C_, D, Q = _inputs(case, dtype=jnp.bfloat16)
    ref = ssd_reference(x.astype(jnp.float32), dt, A,
                        B_.astype(jnp.float32), C_.astype(jnp.float32), D)
    out = ssd_forward_kernel(x, dt, A, B_, C_, D, chunk=Q, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=0.15, rtol=0.1)


@pytest.mark.parametrize("case", CASES[:3])
def test_pallas_chunk_matches_chunk_ref(case):
    """The kernel's per-chunk outputs (Y_intra, S) match the chunk oracle."""
    B, T, H, P, G, N, Q = case
    x, dt, A, B_, C_, D, _ = _inputs(case, seed=3)
    rep = H // G
    nc = T // Q
    xh = jnp.moveaxis(x, 2, 1).reshape(B * H, nc, Q, P)
    dth = jnp.moveaxis(dt, 2, 1).reshape(B * H, nc, Q)
    Bh = jnp.moveaxis(jnp.repeat(B_, rep, axis=2), 2, 1).reshape(B * H, nc, Q, N)
    Ch = jnp.moveaxis(jnp.repeat(C_, rep, axis=2), 2, 1).reshape(B * H, nc, Q, N)
    la = dth * jnp.tile(A, B)[:, None, None]
    cums = jnp.cumsum(la, axis=2)
    Yk, Sk = ssd_chunk_pallas(Ch, Bh, xh, cums, dth, interpret=True)
    Yr, Sr = ssd_chunk_ref(Ch, Bh, xh, cums, dth)
    np.testing.assert_allclose(np.asarray(Yk), np.asarray(Yr), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(Sk), np.asarray(Sr), atol=2e-4, rtol=2e-4)


def test_chunk_size_invariance():
    case = (1, 96, 2, 8, 1, 8, 0)
    x, dt, A, B_, C_, D, _ = _inputs(case, seed=4)
    outs = [ssd_chunked(x, dt, A, B_, C_, D, chunk=c) for c in (8, 16, 32, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=3e-4, rtol=3e-4)


def test_decode_matches_full_sequence():
    """Recurrent decode == chunked forward, token by token."""
    from repro.configs.base import get_reduced
    from repro.models.ssm import SSMState, init_ssm, ssm_block, ssm_decode_step

    cfg = get_reduced("mamba2-2.7b")
    key = jax.random.PRNGKey(0)
    params = init_ssm(key, cfg)
    B, S = 2, 16
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    full = ssm_block(params, x, cfg)
    state = SSMState.init(B, cfg, x.dtype)
    outs = []
    for t in range(S):
        o, state = ssm_decode_step(params, x[:, t : t + 1], state, cfg)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-3, rtol=2e-2)
