"""Per-arch smoke tests: reduced variant of each assigned architecture runs
one forward + one train step + one decode step on CPU; shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, get_reduced
from repro.models.registry import build_model

# tier-2: heavy reduced-arch smoke battery (~95s) (ROADMAP tier-1 runs -m "not slow")
pytestmark = pytest.mark.slow


def _batch(cfg, key, B=2, S=32):
    batch = {}
    if cfg.embeds_in:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
        batch["labels"] = jax.random.randint(
            jax.random.fold_in(key, 1), (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["cross_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.num_patch_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    m = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    batch = _batch(cfg, key)
    B, S = 2, 32
    logits, aux = m.apply(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = m.loss(new_params, batch)
    assert bool(jnp.isfinite(loss2))
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_reduced(arch)
    m = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    B = 2
    st = m.init_decode_state(B, 16)
    cross_kv = None
    if cfg.family == "vlm":
        pe = jax.random.normal(key, (B, cfg.num_patch_tokens, cfg.d_model))
        cross_kv = m.init_cross_kv(params, pe)
    tok = (jax.random.normal(jax.random.fold_in(key, 1), (B, 1, cfg.d_model))
           if cfg.embeds_in else jnp.zeros((B,), jnp.int32))
    for _ in range(3):
        logits, st = m.decode_step(params, tok, st, cross_kv)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        if not cfg.embeds_in:
            tok = jnp.argmax(logits, axis=-1)
    assert int(st.pos) == 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_assignment(arch):
    """The full configs match the assigned table (never instantiated)."""
    cfg = get_config(arch)
    table = {
        "mamba2_2p7b": (64, 2560, 0, 0, 0, 50280),
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 768, 151936),
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
        "zamba2_2p7b": (54, 2560, 32, 32, 10240, 32000),
        "qwen2p5_32b": (64, 5120, 40, 8, 27648, 152064),
        "qwen2_1p5b": (28, 1536, 12, 2, 8960, 151936),
        "yi_34b": (60, 7168, 56, 8, 20480, 64000),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "llama3p2_vision_11b": (40, 4096, 32, 8, 14336, 128256),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
    }
    L, d, h, kv, ff, v = table[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch
    assert cfg.source  # every config cites its source
    if arch == "mamba2_2p7b":
        assert cfg.ssm_state == 128
    if arch == "zamba2_2p7b":
        assert cfg.ssm_state == 64
    if arch == "qwen3_moe_30b_a3b":
        assert (cfg.num_experts, cfg.experts_per_token) == (128, 8)
    if arch == "olmoe_1b_7b":
        assert (cfg.num_experts, cfg.experts_per_token) == (64, 8)
    if arch in ("qwen2p5_32b", "qwen2_1p5b"):
        assert cfg.qkv_bias


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768 and SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1
