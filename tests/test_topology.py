import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topology import adjacency, is_row_stochastic, metropolis, row_stochastic

TOPOLOGIES = ["cycle", "complete", "star", "erdos"]


@pytest.mark.parametrize("topo", TOPOLOGIES)
@pytest.mark.parametrize("n", [5, 8, 25])
def test_row_stochastic(topo, n):
    key = jax.random.PRNGKey(1)
    adj = adjacency(topo, n, key=key)
    q = row_stochastic(adj)
    assert is_row_stochastic(q)
    # zero diagonal (no self messages, paper Sec 2.2)
    assert float(jnp.abs(jnp.diag(q)).max()) == 0.0


def test_ring2d_matches_torus_degree():
    adj = adjacency("ring2d", 16)
    deg = np.asarray(adj).sum(1)
    assert (deg == 4).all()  # 2D torus: 4 neighbors


def test_cycle_directed_vs_undirected():
    a_dir = adjacency("cycle", 6, directed=True)
    a_und = adjacency("cycle", 6, directed=False)
    assert int(a_dir.sum()) == 6
    assert int(a_und.sum()) == 12


def _strongly_connected(a: np.ndarray) -> bool:
    """Boolean-matrix transitive closure: every node reaches every node."""
    n = a.shape[0]
    reach = a | np.eye(n, dtype=bool)
    for _ in range(int(np.ceil(np.log2(max(n, 2))))):
        reach = reach @ reach
    return bool(reach.all())


@pytest.mark.parametrize("seed", range(5))
def test_erdos_undirected_is_symmetric(seed):
    """directed=False must return a symmetric adjacency — the one-way
    cycle overlay used to silently break this (regression)."""
    a = np.asarray(adjacency("erdos", 12, key=jax.random.PRNGKey(seed), p=0.2))
    np.testing.assert_array_equal(a, a.T)
    assert _strongly_connected(a)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("p", [0.0, 0.15])
def test_erdos_directed_strongly_connected(seed, p):
    """directed=True: the directed Hamiltonian-cycle overlay guarantees
    strong connectivity even with no random edges at all (p=0)."""
    a = np.asarray(adjacency("erdos", 11, key=jax.random.PRNGKey(seed),
                             p=p, directed=True))
    assert _strongly_connected(a)
    assert not a.diagonal().any()


@pytest.mark.parametrize("topo", ["cycle", "complete", "erdos"])
def test_metropolis_doubly_stochastic(topo):
    adj = adjacency(topo, 9, key=jax.random.PRNGKey(3))
    w = metropolis(adj)
    np.testing.assert_allclose(np.asarray(w.sum(0)), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(w.sum(1)), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w).T, atol=1e-6)
    assert (np.asarray(w) >= -1e-7).all()


def test_row_stochastic_weighted():
    adj = adjacency("complete", 5)
    key = jax.random.PRNGKey(0)
    w = jax.random.uniform(key, (5, 5), minval=0.1, maxval=1.0)
    q = row_stochastic(adj, weights=w)
    assert is_row_stochastic(q)
