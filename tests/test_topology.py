import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topology import adjacency, is_row_stochastic, metropolis, row_stochastic

TOPOLOGIES = ["cycle", "complete", "star", "erdos"]


@pytest.mark.parametrize("topo", TOPOLOGIES)
@pytest.mark.parametrize("n", [5, 8, 25])
def test_row_stochastic(topo, n):
    key = jax.random.PRNGKey(1)
    adj = adjacency(topo, n, key=key)
    q = row_stochastic(adj)
    assert is_row_stochastic(q)
    # zero diagonal (no self messages, paper Sec 2.2)
    assert float(jnp.abs(jnp.diag(q)).max()) == 0.0


def test_ring2d_matches_torus_degree():
    adj = adjacency("ring2d", 16)
    deg = np.asarray(adj).sum(1)
    assert (deg == 4).all()  # 2D torus: 4 neighbors


def test_cycle_directed_vs_undirected():
    a_dir = adjacency("cycle", 6, directed=True)
    a_und = adjacency("cycle", 6, directed=False)
    assert int(a_dir.sum()) == 6
    assert int(a_und.sum()) == 12


@pytest.mark.parametrize("topo", ["cycle", "complete", "erdos"])
def test_metropolis_doubly_stochastic(topo):
    adj = adjacency(topo, 9, key=jax.random.PRNGKey(3))
    w = metropolis(adj)
    np.testing.assert_allclose(np.asarray(w.sum(0)), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(w.sum(1)), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(w), np.asarray(w).T, atol=1e-6)
    assert (np.asarray(w) >= -1e-7).all()


def test_row_stochastic_weighted():
    adj = adjacency("complete", 5)
    key = jax.random.PRNGKey(0)
    w = jax.random.uniform(key, (5, 5), minval=0.1, maxval=1.0)
    q = row_stochastic(adj, weights=w)
    assert is_row_stochastic(q)
