"""Sweep-engine parity: every grid cell == the solo `simulate()` run.

The acceptance bar for the batched sweep engine: row `(g, k)` of a
`simulate_sweep` call must be **bit-identical** (f32) to a solo
`simulate()` with config `g` / seed `k` on one device — across the
vmapped seed axis, the scanned traced-override config axis (incl. the
psi<=0 "unbounded" encoding), the stacked-schedule scenario axis, and
for baselines as well as DRACO."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import make_context, simulate, simulate_sweep
from repro.api.sweep import SWEEPABLE, stack_configs
from repro.core.channel import ChannelConfig
from repro.core.protocol import DracoConfig
from repro.data.synthetic import federated_classification, make_mlp

# tier-2: sweep-engine bitwise parity battery (ROADMAP tier-1 runs -m "not slow")
pytestmark = pytest.mark.slow

N = 5


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    train, test = federated_classification(k1, N, input_dim=6, num_classes=3,
                                           per_client=64)
    params0, apply, loss, acc = make_mlp(k2, 6, (8,), 3)
    return train, test, params0, loss, acc


def _cfg(**kw):
    base = dict(num_clients=N, lr=0.1, local_batches=1, batch_size=8,
                lambda_grad=0.8, lambda_tx=0.8, unify_period=10, psi=2,
                topology="complete", max_delay_windows=3, channel=None)
    base.update(kw)
    return DracoConfig(**base)


KEYS = jax.random.split(jax.random.PRNGKey(42), 2)


def _assert_cell_equal(solo_state, finals, g, k):
    for a, b in zip(jax.tree_util.tree_leaves(solo_state.params),
                    jax.tree_util.tree_leaves(finals.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b[g, k]))


def test_seed_axis_bitwise_parity_draco(task):
    """vmapped seed rows == solo runs, wireless channel + Psi cap on,
    incl. the trace (with its final partial-chunk row)."""
    train, test, params0, loss, acc = task
    cfg = _cfg(channel=ChannelConfig(message_bytes=51_640, gamma_max=10.0))
    finals, trace = simulate_sweep("draco", cfg, params0, loss, train, 10,
                                   keys=KEYS, eval_every=4, eval_fn=acc,
                                   eval_data=test)
    assert trace.metrics["accuracy"].shape == (1, len(KEYS), 3)
    assert list(trace.step) == [4, 8, 10]
    for k, key in enumerate(KEYS):
        solo, solo_tr = simulate("draco", cfg, params0, loss, train, 10,
                                 key=key, eval_every=4, eval_fn=acc,
                                 eval_data=test)
        _assert_cell_equal(solo, finals, 0, k)
        np.testing.assert_array_equal(np.asarray(solo_tr.metrics["accuracy"]),
                                      trace.metrics["accuracy"][0, k])
        np.testing.assert_array_equal(np.asarray(solo.total_accept),
                                      np.asarray(finals.total_accept[0, k]))


def test_config_axis_bitwise_parity(task):
    """Traced lr/psi overrides == static-config solo runs, including the
    psi=0 row (the unbounded encoding must match the static fast path)."""
    train, test, params0, loss, acc = task
    grid = [_cfg(psi=0, lr=0.1), _cfg(psi=2, lr=0.1), _cfg(psi=3, lr=0.05)]
    finals, trace = simulate_sweep("draco", grid, params0, loss, train, 8,
                                   keys=KEYS, eval_every=4, eval_fn=acc,
                                   eval_data=test)
    assert trace.metrics["accuracy"].shape == (3, len(KEYS), 2)
    for g, cfg in enumerate(grid):
        solo, _ = simulate("draco", cfg, params0, loss, train, 8, key=KEYS[1],
                           eval_every=4, eval_fn=acc, eval_data=test)
        _assert_cell_equal(solo, finals, g, 1)


@pytest.mark.parametrize("method", ["sync-push"])
def test_baseline_parity(method, task):
    """A baseline rides the same engine: seed axis + lr config axis."""
    train, test, params0, loss, acc = task
    grid = [_cfg(topology="cycle", lr=0.1), _cfg(topology="cycle", lr=0.02)]
    finals, _ = simulate_sweep(method, grid, params0, loss, train, 6,
                               keys=KEYS)
    for g, cfg in enumerate(grid):
        for k, key in enumerate(KEYS):
            solo, _ = simulate(method, cfg, params0, loss, train, 6, key=key)
            _assert_cell_equal(solo, finals, g, k)
            np.testing.assert_array_equal(
                np.asarray(solo.push_weight),
                np.asarray(finals.push_weight[g, k]))


def test_dynamic_scenario_parity(task):
    """Stacked-schedule grid rows == solo runs with per-point contexts."""
    from repro.scenarios import make_schedule

    train, test, params0, loss, acc = task
    cfg = _cfg()
    key = jax.random.PRNGKey(3)
    scheds = [make_schedule("markov-edge-flip", cfg,
                            key=jax.random.fold_in(key, i), steps=6, churn=c)
              for i, c in enumerate((0.1, 0.4))]
    finals, _ = simulate_sweep("draco", cfg, params0, loss, train, 8,
                               keys=KEYS, schedules=scheds)
    for g, sched in enumerate(scheds):
        ctx = make_context(cfg, loss, train, params0=params0, scenario=sched)
        solo, _ = simulate("draco", cfg, params0, loss, train, 8, key=KEYS[0],
                           ctx=ctx)
        _assert_cell_equal(solo, finals, g, 0)


def test_final_fn_slims_output(task):
    train, test, params0, loss, acc = task
    grid = [_cfg(psi=1), _cfg(psi=2)]

    finals, _ = simulate_sweep("draco", grid, params0, loss, train, 4,
                               keys=KEYS, final_fn=_take_accept)
    assert finals.shape == (2, len(KEYS), N)
    assert finals.dtype == jnp.int32


def _take_accept(state):
    return state.total_accept


def test_stack_configs_detects_swept_fields():
    grid = [_cfg(psi=1, lr=0.1), _cfg(psi=4, lr=0.1)]
    base, ov = stack_configs(grid)
    assert base == grid[0]
    assert ov.lr is None and ov.lambda_grad is None
    np.testing.assert_array_equal(np.asarray(ov.psi), [1, 4])
    assert ov.psi.dtype == jnp.int32
    assert set(SWEEPABLE) == {"lr", "lambda_grad", "lambda_tx", "psi"}


def test_rejects_nonsweepable_grid(task):
    train, _, params0, loss, _ = task
    with pytest.raises(ValueError, match="non-sweepable"):
        simulate_sweep("draco", [_cfg(), _cfg(topology="cycle")], params0,
                       loss, train, 2, keys=KEYS)


def test_rejects_identical_config_grid(task):
    train, _, params0, loss, _ = task
    with pytest.raises(ValueError, match="no field varies"):
        simulate_sweep("draco", [_cfg(psi=1), _cfg(psi=1)], params0, loss,
                       train, 2, keys=KEYS)


def test_rejects_field_algo_ignores(task):
    train, _, params0, loss, _ = task
    with pytest.raises(ValueError, match="does not consume"):
        simulate_sweep("sync-push", [_cfg(psi=1), _cfg(psi=2)], params0,
                       loss, train, 2, keys=KEYS)


def test_rejects_mismatched_grid_axes(task):
    from repro.scenarios import make_schedule

    train, _, params0, loss, _ = task
    cfg = _cfg()
    scheds = [make_schedule("markov-edge-flip", cfg,
                            key=jax.random.PRNGKey(i), steps=4, churn=0.2)
              for i in range(3)]
    with pytest.raises(ValueError, match="grid axes disagree"):
        simulate_sweep("draco", [cfg.replace(psi=1), cfg.replace(psi=2)],
                       params0, loss, train, 2, keys=KEYS, schedules=scheds)


def test_requires_keys(task):
    train, _, params0, loss, _ = task
    with pytest.raises(ValueError, match="keys"):
        simulate_sweep("draco", _cfg(), params0, loss, train, 2)
