"""Fixture corpus for `repro.analysis`: per rule, at least one minimal
known-bad snippet (asserting the exact rule id and line) and one
known-good snippet, plus the suppression grammar (reasoned suppressions
silence; bare ones are rejected and do not silence).

Pure stdlib — the analyzer never imports jax, so this battery stays in
tier-1.
"""
import textwrap

from repro.analysis import RULES, analyze_paths, report_json
from repro.analysis.core import SUPPRESS_NO_REASON, analyze_file


def run(text, path="src/repro/mod.py", rule=None):
    rules = [RULES[rule]] if rule else None
    return analyze_file(path, rules=rules, text=textwrap.dedent(text))


def lines_of(findings, rule):
    return [f.line for f in findings if f.rule == rule]


# -- RNG-KEY-REUSE -----------------------------------------------------------

def test_rng_key_reuse_bad():
    findings = run(
        """\
        import jax

        def f(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))
            return a + b
        """, rule="RNG-KEY-REUSE")
    assert lines_of(findings, "RNG-KEY-REUSE") == [5]


def test_rng_split_reuse_bad():
    # consuming a key with split and then sampling from it is the
    # classic replay-correlation bug
    findings = run(
        """\
        import jax

        def f(key):
            ks = jax.random.split(key, 4)
            return jax.random.normal(key, (3,)), ks
        """, rule="RNG-KEY-REUSE")
    assert lines_of(findings, "RNG-KEY-REUSE") == [5]


def test_rng_loop_carried_reuse_bad():
    findings = run(
        """\
        import jax

        def f(key, xs):
            out = []
            for x in xs:
                out.append(jax.random.normal(key, (3,)) + x)
            return out
        """, rule="RNG-KEY-REUSE")
    assert lines_of(findings, "RNG-KEY-REUSE") == [6]


def test_rng_split_discipline_good():
    findings = run(
        """\
        import jax

        def f(key):
            k1, k2 = jax.random.split(key)
            a = jax.random.normal(k1, (3,))
            b = jax.random.uniform(k2, (3,))
            return a + b
        """, rule="RNG-KEY-REUSE")
    assert findings == []


def test_rng_fold_in_idiom_good():
    # fold_in derives fresh streams; it neither consumes nor collides
    findings = run(
        """\
        import jax

        def f(key, xs):
            base = jax.random.normal(key, (3,))
            outs = [jax.random.normal(jax.random.fold_in(key, i), (3,))
                    for i in range(3)]
            return base, outs
        """, rule="RNG-KEY-REUSE")
    assert findings == []


def test_rng_early_return_branches_good():
    findings = run(
        """\
        import jax

        def f(key, flag):
            if flag:
                a, b = jax.random.split(key)
                return a, b
            a, b, c = jax.random.split(key, 3)
            return a, c
        """, rule="RNG-KEY-REUSE")
    assert findings == []


# -- TRACED-PY-BRANCH --------------------------------------------------------

def test_traced_branch_bad():
    findings = run(
        """\
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
        """, rule="TRACED-PY-BRANCH")
    assert lines_of(findings, "TRACED-PY-BRANCH") == [5]


def test_traced_branch_scan_body_bad():
    findings = run(
        """\
        import jax

        def body(carry, x):
            while carry > 0:
                carry = carry - x
            return carry, x

        def run(c0, xs):
            return jax.lax.scan(body, c0, xs)
        """, rule="TRACED-PY-BRANCH")
    assert lines_of(findings, "TRACED-PY-BRANCH") == [4]


def test_traced_branch_static_param_good():
    # cfg-named params, literal-default knobs, shape reads and
    # isinstance narrowing are all static — no findings
    findings = run(
        """\
        import jax

        @jax.jit
        def f(x, cfg, n: int = 4):
            if cfg.debug:
                return x * n
            if x.ndim > 1:
                x = x.sum(0)
            if isinstance(x, tuple):
                x = x[0]
            return x
        """, rule="TRACED-PY-BRANCH")
    assert findings == []


def test_traced_branch_static_argnames_good():
    findings = run(
        """\
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames=("mode",))
        def f(x, mode):
            if mode == "fast":
                return x
            return x * 2
        """, rule="TRACED-PY-BRANCH")
    assert findings == []


# -- HOST-SYNC-IN-JIT --------------------------------------------------------

def test_host_sync_bad():
    findings = run(
        """\
        import jax

        @jax.jit
        def f(x):
            print(x)
            return float(x.sum())
        """, rule="HOST-SYNC-IN-JIT")
    assert lines_of(findings, "HOST-SYNC-IN-JIT") == [5, 6]


def test_host_sync_item_bad():
    findings = run(
        """\
        import jax

        @jax.jit
        def f(x):
            y = x.sum()
            return y.item()
        """, rule="HOST-SYNC-IN-JIT")
    assert lines_of(findings, "HOST-SYNC-IN-JIT") == [6]


def test_host_sync_outside_jit_good():
    findings = run(
        """\
        import numpy as np

        def report(x):
            print(x)
            return float(np.asarray(x).sum())
        """, rule="HOST-SYNC-IN-JIT")
    assert findings == []


# -- JIT-RECOMPILE-HAZARD ----------------------------------------------------

def test_jit_dict_param_bad():
    findings = run(
        """\
        import jax

        @jax.jit
        def f(table: dict, x):
            return table["w"] + x
        """, rule="JIT-RECOMPILE-HAZARD")
    assert lines_of(findings, "JIT-RECOMPILE-HAZARD") == [4]


def test_jit_immediate_invoke_bad():
    findings = run(
        """\
        import jax

        def f(x):
            return jax.jit(lambda a: a + 1)(x)
        """, rule="JIT-RECOMPILE-HAZARD")
    assert lines_of(findings, "JIT-RECOMPILE-HAZARD") == [4]


def test_jit_in_loop_bad():
    findings = run(
        """\
        import jax

        def f(xs, g):
            out = []
            for x in xs:
                step = jax.jit(g)
                out.append(step(x))
            return out
        """, rule="JIT-RECOMPILE-HAZARD")
    assert lines_of(findings, "JIT-RECOMPILE-HAZARD") == [6]


def test_jit_static_argnames_dict_good():
    findings = run(
        """\
        import jax
        from functools import partial

        @partial(jax.jit, static_argnames=("table",))
        def f(table: dict, x):
            return x

        def outer(g, x):
            step = jax.jit(g)
            return step(x), step(x)
        """, rule="JIT-RECOMPILE-HAZARD")
    assert findings == []


# -- DTYPE-PLANE-CONTRACT ----------------------------------------------------

def test_plane_contract_mismatch_bad():
    findings = run(
        """\
        def mix(q, flat):
            \"\"\"q (N, N) weights, flat (N, D) updates.\"\"\"
            return q.T @ flat
        """, path="src/repro/core/flat.py", rule="DTYPE-PLANE-CONTRACT")
    assert lines_of(findings, "DTYPE-PLANE-CONTRACT") == [1]
    assert "(N,D)" in findings[0].message


def test_plane_contract_missing_docstring_bad():
    findings = run(
        """\
        def drain(w_ring, buffer):
            return (w_ring, buffer)
        """, path="src/repro/events/engine.py", rule="DTYPE-PLANE-CONTRACT")
    assert lines_of(findings, "DTYPE-PLANE-CONTRACT") == [1]


def test_plane_contract_good():
    findings = run(
        """\
        def mix(q, flat):
            \"\"\"q (N, N) row-stochastic, flat (N, Dflat) updates.\"\"\"
            return q.T @ flat

        def _private(flat):
            return flat

        def no_planes(x, y):
            return x + y
        """, path="src/repro/core/flat.py", rule="DTYPE-PLANE-CONTRACT")
    assert findings == []


def test_plane_contract_out_of_scope_good():
    findings = run(
        """\
        def mix(q, flat):
            return q.T @ flat
        """, path="src/repro/api/simulate.py", rule="DTYPE-PLANE-CONTRACT")
    assert findings == []


# -- MARKER-DISCIPLINE -------------------------------------------------------

def test_marker_battery_file_bad():
    findings = run(
        """\
        import pytest

        def test_engines_agree():
            assert True
        """, path="tests/test_foo_parity.py", rule="MARKER-DISCIPLINE")
    assert lines_of(findings, "MARKER-DISCIPLINE") == [3]


def test_marker_hypothesis_bad():
    findings = run(
        """\
        from hypothesis import given, strategies as st

        @given(n=st.integers(1, 9))
        def test_fuzz(n):
            assert n > 0
        """, path="tests/test_foo.py", rule="MARKER-DISCIPLINE")
    # findings anchor to the `def` line, below the @given decorator
    assert lines_of(findings, "MARKER-DISCIPLINE") == [4]


def test_marker_module_pytestmark_good():
    findings = run(
        """\
        import pytest

        pytestmark = pytest.mark.slow

        def test_engines_agree():
            assert True
        """, path="tests/test_foo_parity.py", rule="MARKER-DISCIPLINE")
    assert findings == []


def test_marker_decorated_good():
    findings = run(
        """\
        import pytest
        from hypothesis import given, strategies as st

        @pytest.mark.slow
        @given(n=st.integers(1, 9))
        def test_fuzz(n):
            assert n > 0
        """, path="tests/test_foo.py", rule="MARKER-DISCIPLINE")
    assert findings == []


# -- suppressions ------------------------------------------------------------

_REUSE = """\
import jax

def f(key):
    a = jax.random.normal(key, (3,))
    {comment}
    b = jax.random.uniform(key, (3,))
    return a + b
"""


def test_suppression_with_reason_silences():
    text = _REUSE.format(
        comment="# repro-lint: disable=RNG-KEY-REUSE(correlated streams "
                "are the point of this fixture)")
    findings = run(text, rule="RNG-KEY-REUSE")
    assert findings == []


def test_suppression_without_reason_rejected():
    text = _REUSE.format(comment="# repro-lint: disable=RNG-KEY-REUSE")
    findings = run(text, rule="RNG-KEY-REUSE")
    # the bare suppression is itself a finding, and it does NOT silence
    assert lines_of(findings, SUPPRESS_NO_REASON) == [5]
    assert lines_of(findings, "RNG-KEY-REUSE") == [6]


def test_suppression_trailing_comment_same_line():
    findings = run(
        """\
        import jax

        def f(key):
            a = jax.random.normal(key, (3,))
            b = jax.random.uniform(key, (3,))  # repro-lint: disable=RNG-KEY-REUSE(same-stream comparison on purpose)
            return a + b
        """, rule="RNG-KEY-REUSE")
    assert findings == []


def test_suppression_wrong_rule_does_not_silence():
    text = _REUSE.format(
        comment="# repro-lint: disable=TRACED-PY-BRANCH(unrelated rule)")
    findings = run(text, rule="RNG-KEY-REUSE")
    assert lines_of(findings, "RNG-KEY-REUSE") == [6]


# -- engine plumbing ---------------------------------------------------------

def test_all_rules_registered():
    assert {"RNG-KEY-REUSE", "TRACED-PY-BRANCH", "HOST-SYNC-IN-JIT",
            "JIT-RECOMPILE-HAZARD", "DTYPE-PLANE-CONTRACT",
            "MARKER-DISCIPLINE"} <= set(RULES)


def test_parse_error_reported_not_crashed():
    findings = run("def broken(:\n    pass\n")
    assert [f.rule for f in findings] == ["PARSE-ERROR"]


def test_json_report_shape():
    import json

    findings = run(_REUSE.format(comment="pass"), rule="RNG-KEY-REUSE")
    payload = json.loads(report_json(findings, files_scanned=1))
    assert payload["version"] == 1
    assert payload["files_scanned"] == 1
    assert payload["counts"] == {"RNG-KEY-REUSE": 1}
    f = payload["findings"][0]
    assert f["rule"] == "RNG-KEY-REUSE" and f["path"] == "src/repro/mod.py"


def test_repo_tree_is_clean():
    """The committed tree must stay lint-clean (the CI gate)."""
    findings, files = analyze_paths(["src", "tests"])
    assert files > 0
    assert [f.format() for f in findings] == []
