import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixing import mix_dense, psi_cap_mask, receive_counts
from repro.core.topology import adjacency, row_stochastic


def test_mix_dense_matches_manual():
    key = jax.random.PRNGKey(0)
    n, d = 5, 7
    q = jax.nn.softmax(jax.random.normal(key, (n, n)))
    deltas = {"w": jax.random.normal(jax.random.fold_in(key, 1), (n, d)),
              "b": jax.random.normal(jax.random.fold_in(key, 2), (n,))}
    out = mix_dense(q, deltas)
    np.testing.assert_allclose(
        np.asarray(out["w"]), np.asarray(q).T @ np.asarray(deltas["w"]), rtol=2e-5)
    np.testing.assert_allclose(
        np.asarray(out["b"]), np.asarray(q).T @ np.asarray(deltas["b"]), rtol=2e-5)


def test_mix_dense_kernel_path():
    key = jax.random.PRNGKey(1)
    n, d = 8, 33
    q = jax.nn.softmax(jax.random.normal(key, (n, n)))
    deltas = {"w": jax.random.normal(jax.random.fold_in(key, 1), (n, d))}
    ref = mix_dense(q, deltas)
    out = mix_dense(q, deltas, use_kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref["w"]),
                               atol=1e-5, rtol=1e-5)


def test_psi_cap_column_budget():
    key = jax.random.PRNGKey(2)
    n, psi = 10, 3
    q = row_stochastic(adjacency("complete", n))
    capped = psi_cap_mask(key, q, psi)
    incoming = np.asarray((capped > 0).sum(0))
    assert (incoming <= psi).all()
    # kept weights unchanged where kept
    kept = np.asarray(capped)
    orig = np.asarray(q)
    mask = kept > 0
    np.testing.assert_allclose(kept[mask], orig[mask])


def test_psi_cap_noop_when_large():
    key = jax.random.PRNGKey(3)
    q = row_stochastic(adjacency("complete", 6))
    capped = psi_cap_mask(key, q, 100)
    np.testing.assert_array_equal(np.asarray(capped), np.asarray(q))


def test_receive_counts():
    q = jnp.array([[0.0, 1.0], [0.5, 0.0]])
    np.testing.assert_array_equal(np.asarray(receive_counts(q)), [1, 1])
