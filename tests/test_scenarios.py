"""Unit tests for the scenario engine: rings, registry, generators."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.channel import ChannelConfig, geometric_adjacency, waypoint_step
from repro.core.protocol import DracoConfig
from repro.scenarios import (
    Schedule,
    get_scenario,
    list_scenarios,
    make_schedule,
    validate_schedule,
)

ALL_GENERATORS = ("markov-edge-flip", "random-waypoint", "static",
                  "straggler-profile")


def _cfg(**kw):
    base = dict(num_clients=7, topology="cycle")
    base.update(kw)
    return DracoConfig(**base)


def test_registry_lists_builtins():
    assert list_scenarios() == ALL_GENERATORS
    for name in ALL_GENERATORS:
        assert callable(get_scenario(name))
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


def test_make_schedule_passthrough_and_knob_guard():
    cfg = _cfg()
    sched = make_schedule("static", cfg)
    assert make_schedule(sched, cfg) is sched
    with pytest.raises(ValueError, match="knobs"):
        make_schedule(sched, cfg, steps=4)


def test_per_field_ring_periods():
    """Fields ring at their own periods: a straggler profile stores the
    frozen graph once next to a T-long rate ring, and `at` wraps each
    field by its own leading dim."""
    cfg = _cfg()
    sched = make_schedule("straggler-profile", cfg, key=jax.random.PRNGKey(0),
                          steps=6, straggler_frac=0.5, duty=0.5)
    assert sched.q.shape[0] == 1
    assert sched.compute_rate.shape == (6, cfg.num_clients)
    assert sched.period == 6
    for t in (0, 3, 6, 13):
        snap = sched.at(t)
        np.testing.assert_array_equal(np.asarray(snap.q),
                                      np.asarray(sched.q[0]))
        np.testing.assert_array_equal(np.asarray(snap.compute_rate),
                                      np.asarray(sched.compute_rate[t % 6]))


def test_schedule_at_traceable():
    cfg = _cfg()
    sched = make_schedule("markov-edge-flip", cfg, key=jax.random.PRNGKey(1),
                          steps=4)
    q_at = jax.jit(lambda s, t: s.at(t).q)
    q3 = q_at(sched, jnp.int32(3))
    np.testing.assert_array_equal(np.asarray(q3), np.asarray(sched.q[3]))


@pytest.mark.parametrize("name", ALL_GENERATORS)
def test_generators_validate(name):
    cfg = _cfg(topology="erdos")
    kw = {} if name == "static" else {"steps": 8}
    sched = make_schedule(name, cfg, key=jax.random.PRNGKey(2), **kw)
    validate_schedule(sched)
    assert sched.num_clients == cfg.num_clients


def test_markov_churn_zero_freezes_base():
    cfg = _cfg(topology="complete")
    sched = make_schedule("markov-edge-flip", cfg, key=jax.random.PRNGKey(3),
                          steps=5, churn=0.0)
    for t in range(1, 5):
        np.testing.assert_array_equal(np.asarray(sched.adj[t]),
                                      np.asarray(sched.adj[0]))


def test_markov_dense_base_preserves_density():
    """On dense bases the off->on rate saturates; the chain must scale
    both rates together so the stationary edge density stays at the
    base's (a churn sweep holds connectivity fixed, regression)."""
    cfg = _cfg(num_clients=12, topology="complete")
    sched = make_schedule("markov-edge-flip", cfg, key=jax.random.PRNGKey(9),
                          steps=40, churn=0.5, keep_connected=False)
    off = ~np.eye(12, dtype=bool)
    densities = [np.asarray(sched.adj[t])[off].mean() for t in range(40)]
    # stationary density is clipped to 0.95 for a complete base; the
    # time-average must stay near it instead of drifting to 1/(1+churn)
    assert np.mean(densities[10:]) > 0.9


def test_markov_churn_actually_churns():
    cfg = _cfg(num_clients=10, topology="erdos")
    sched = make_schedule("markov-edge-flip", cfg, key=jax.random.PRNGKey(4),
                          steps=8, churn=0.5)
    diffs = sum(int((np.asarray(sched.adj[t]) != np.asarray(sched.adj[t - 1])).sum())
                for t in range(1, 8))
    assert diffs > 0


def test_waypoint_positions_in_disk_and_speed_bounded():
    cfg = _cfg(channel=ChannelConfig())
    speed = 30.0
    sched = make_schedule("random-waypoint", cfg, key=jax.random.PRNGKey(5),
                          steps=10, speed=speed)
    pos = np.asarray(sched.positions)
    radii = np.linalg.norm(pos, axis=-1)
    assert radii.max() <= cfg.channel.radius + 1e-3
    hops = np.linalg.norm(np.diff(pos, axis=0), axis=-1)
    assert hops.max() <= speed + 1e-3


def test_waypoint_adjacency_matches_geometry():
    cfg = _cfg(channel=ChannelConfig())
    frac = 0.5
    sched = make_schedule("random-waypoint", cfg, key=jax.random.PRNGKey(6),
                          steps=4, comm_radius_frac=frac, keep_connected=False)
    for t in range(4):
        want = geometric_adjacency(sched.positions[t],
                                   frac * cfg.channel.radius)
        np.testing.assert_array_equal(np.asarray(sched.adj[t]),
                                      np.asarray(want))


def test_waypoint_step_snaps_and_advances():
    pos = jnp.array([[0.0, 0.0], [10.0, 0.0]])
    wp = jnp.array([[100.0, 0.0], [12.0, 0.0]])
    new, arrived = waypoint_step(pos, wp, 5.0)
    np.testing.assert_allclose(np.asarray(new[0]), [5.0, 0.0], atol=1e-6)
    np.testing.assert_allclose(np.asarray(new[1]), [12.0, 0.0], atol=1e-6)
    assert not bool(arrived[0]) and bool(arrived[1])


def test_straggler_rates_structure():
    cfg = _cfg(num_clients=10)
    sched = make_schedule("straggler-profile", cfg, key=jax.random.PRNGKey(7),
                          steps=12, straggler_frac=0.4, slowdown=8.0,
                          duty=1.0)
    rate = np.asarray(sched.compute_rate)
    assert ((rate >= 0) & (rate <= 1)).all()
    const = rate[0]
    # duty=1.0: the ring is constant in time
    assert (rate == const[None, :]).all()
    slow = const < 1.0
    assert slow.sum() == 4  # straggler_frac * n
    assert (const[~slow] == 1.0).all()
    assert (const[slow] <= 1.0 / 8.0).all()  # at least `slowdown` slower
    assert sched.tx_rate is None  # comms schedule untouched by default


def test_straggler_duty_cycle_gates_stragglers_only():
    cfg = _cfg(num_clients=10)
    sched = make_schedule("straggler-profile", cfg, key=jax.random.PRNGKey(8),
                          steps=10, straggler_frac=0.5, duty=0.3)
    rate = np.asarray(sched.compute_rate)
    slow = rate.max(axis=0) < 1.0
    off_fraction = (rate[:, slow] == 0.0).mean(axis=0)
    assert ((off_fraction > 0) & (off_fraction < 1)).all()
    # non-stragglers are never gated
    assert (rate[:, ~slow] == 1.0).all()


def test_geometric_adjacency_basic():
    pos = jnp.array([[0.0, 0.0], [3.0, 0.0], [100.0, 0.0]])
    adj = np.asarray(geometric_adjacency(pos, 5.0))
    assert adj[0, 1] and adj[1, 0]
    assert not adj[0, 2] and not adj[2, 0]
    assert not adj.diagonal().any()
