"""Flat parameter plane: exact ravel/unravel + static spec properties."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flat import ravel_clients, spec_for, spec_of, unravel_clients

N = 5


def _tree(key, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (N, 7, 3)).astype(dtype),
        "b1": jax.random.normal(k2, (N, 3)).astype(dtype),
        "scalar": jax.random.normal(k3, (N,)).astype(dtype),
    }


def test_roundtrip_bitwise():
    tree = _tree(jax.random.PRNGKey(0))
    spec = spec_of(tree)
    flat = ravel_clients(tree)
    assert flat.shape == (N, spec.dim)
    assert spec.dim == 7 * 3 + 3 + 1
    back = unravel_clients(flat, spec)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_offsets_are_column_ranges():
    tree = _tree(jax.random.PRNGKey(1))
    spec = spec_of(tree)
    flat = ravel_clients(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    for leaf, off, size in zip(leaves, spec.offsets, spec.sizes):
        np.testing.assert_array_equal(
            np.asarray(flat[:, off:off + size]),
            np.asarray(leaf.reshape(N, -1)))
    assert spec.offsets[0] == 0
    assert spec.offsets[-1] + spec.sizes[-1] == spec.dim


def test_spec_is_hashable_static_metadata():
    """The spec must ride through jit as aux data: hashable and stable."""
    t1, t2 = _tree(jax.random.PRNGKey(2)), _tree(jax.random.PRNGKey(3))
    s1, s2 = spec_of(t1), spec_of(t2)
    assert hash(s1) == hash(s2) and s1 == s2  # value-independent
    assert s1.num_clients == N


def test_spec_for_matches_replicated_layout():
    params0 = {"w": jnp.zeros((4, 2)), "b": jnp.zeros((2,))}
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (N,) + p.shape), params0)
    assert spec_for(params0, N) == spec_of(stacked)


def test_dtype_cast_and_restore():
    tree = _tree(jax.random.PRNGKey(4), dtype=jnp.bfloat16)
    spec = spec_of(tree)
    flat = ravel_clients(tree)  # default f32 plane
    assert flat.dtype == jnp.float32
    back = unravel_clients(flat, spec)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert b.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_ravel_inside_jit():
    tree = _tree(jax.random.PRNGKey(5))
    spec = spec_of(tree)
    f = jax.jit(lambda t: unravel_clients(ravel_clients(t), spec))
    out = f(tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
