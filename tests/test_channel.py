import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import (
    ChannelConfig,
    LIGHTSPEED,
    interference,
    pairwise_dist,
    place_nodes,
    transmission_delays,
)


def _setup(n=12, seed=0, **kw):
    cfg = ChannelConfig(**kw)
    key = jax.random.PRNGKey(seed)
    pos = place_nodes(key, n, cfg)
    return cfg, key, pos


def test_placement_in_disk():
    cfg, key, pos = _setup(n=100)
    r = jnp.linalg.norm(pos, axis=-1)
    assert float(r.max()) <= cfg.radius + 1e-3


def test_delays_success_subset_of_tx():
    cfg, key, pos = _setup(message_bytes=51_640, gamma_max=10.0)
    tx = jnp.array([True] * 6 + [False] * 6)
    gamma, succ = transmission_delays(jax.random.fold_in(key, 1), pos, tx, cfg)
    # non-transmitting rows cannot succeed
    assert not bool(succ[6:].any())
    assert bool(succ[:6].any())  # some links work at these defaults


def test_delay_at_least_propagation():
    cfg, key, pos = _setup()
    tx = jnp.ones((12,), bool)
    gamma, _ = transmission_delays(jax.random.fold_in(key, 2), pos, tx, cfg)
    dist = pairwise_dist(pos)
    assert bool((gamma >= dist / LIGHTSPEED - 1e-9).all())


def test_tight_deadline_kills_links():
    cfg, key, pos = _setup(gamma_max=1e-9)
    tx = jnp.ones((12,), bool)
    _, succ = transmission_delays(jax.random.fold_in(key, 3), pos, tx, cfg)
    assert not bool(succ.any())


def test_bigger_message_slower():
    key = jax.random.PRNGKey(5)
    cfg_small = ChannelConfig(message_bytes=10_000)
    cfg_big = ChannelConfig(message_bytes=10_000_000)
    pos = place_nodes(key, 8, cfg_small)
    tx = jnp.ones((8,), bool)
    k = jax.random.fold_in(key, 1)
    g_small, _ = transmission_delays(k, pos, tx, cfg_small)
    g_big, _ = transmission_delays(k, pos, tx, cfg_big)
    assert bool((g_big >= g_small).all())


# --------------------------------------------------------------------------
# Regression battery: silent-mask, interference sign, deadline boundary
# --------------------------------------------------------------------------


def test_all_tx_false_yields_no_successes():
    """A silent network (tx_mask all False) can produce zero successful
    links — and zero interference on every hypothetical link."""
    cfg, key, pos = _setup(n=10, message_bytes=10_000)
    tx = jnp.zeros((10,), bool)
    gamma, succ = transmission_delays(jax.random.fold_in(key, 7), pos, tx, cfg)
    assert not bool(succ.any())
    assert bool(jnp.isfinite(gamma).all())
    dist = pairwise_dist(pos)
    p_rx = jax.random.exponential(jax.random.fold_in(key, 8), (10, 10))
    assert float(interference(dist, p_rx, tx, cfg).max()) == 0.0


def test_interference_self_subtraction_never_negative():
    """The self-subtraction removes one term of the sum it belongs to, so
    interference is >= 0 both with the clamp (exactly) and without it
    (up to f32 rounding) — dense clusters maximize cancellation error."""
    cfg = ChannelConfig(interference_radius_frac=1.0)  # everyone is close
    key = jax.random.PRNGKey(17)
    for seed in range(5):
        k = jax.random.fold_in(key, seed)
        pos = place_nodes(k, 16, cfg) * 0.01  # dense cluster
        dist = pairwise_dist(pos)
        p_rx = cfg.tx_power_w * jax.random.exponential(
            jax.random.fold_in(k, 1), (16, 16)) * dist ** (-cfg.path_loss_exp)
        tx = jax.random.uniform(jax.random.fold_in(k, 2), (16,)) < 0.7
        interf = interference(dist, p_rx, tx, cfg)
        assert float(interf.min()) >= 0.0
        # the unclamped subtraction: a sum minus one of its own terms
        contrib = np.where(np.asarray((dist <= cfg.interference_radius_frac
                                       * cfg.radius) & tx[:, None]),
                           np.asarray(p_rx), 0.0)
        raw = contrib.sum(axis=0)[None, :] - contrib
        assert raw.min() >= -1e-6 * max(contrib.sum(), 1.0)


def test_interference_single_transmitter_sees_none():
    """With exactly one close transmitter i, link i -> j suffers zero
    interference (its own signal is fully subtracted)."""
    cfg = ChannelConfig(interference_radius_frac=1.0)
    pos = jnp.array([[0.0, 0.0], [10.0, 0.0], [20.0, 0.0]])
    dist = pairwise_dist(pos)
    p_rx = jnp.ones((3, 3))
    tx = jnp.array([True, False, False])  # only node 0 transmits
    interf = np.asarray(interference(dist, p_rx, tx, cfg))
    assert (interf[0] == 0.0).all()  # links 0 -> j: own signal removed
    assert (interf[1:] == 1.0).all()  # other senders see node 0's power


def test_success_respects_gamma_max_exactly_at_boundary():
    """success is Gamma <= gamma_max: a deadline set to a link's exact
    delay keeps the link; one f32 ulp below kills it."""
    cfg, key, pos = _setup(n=8, message_bytes=51_640, gamma_max=1e9)
    tx = jnp.ones((8,), bool)
    k = jax.random.fold_in(key, 4)
    gamma, succ = transmission_delays(k, pos, tx, cfg)
    g = float(np.asarray(gamma)[0, 1])  # exact f32 value of one delay

    at = dataclasses.replace(cfg, gamma_max=g)
    _, succ_at = transmission_delays(k, pos, tx, at)  # same key, same fading
    assert bool(succ_at[0, 1])

    below = dataclasses.replace(
        cfg, gamma_max=float(np.nextafter(np.float32(g), np.float32(0))))
    _, succ_below = transmission_delays(k, pos, tx, below)
    assert not bool(succ_below[0, 1])
