import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import (
    ChannelConfig,
    LIGHTSPEED,
    pairwise_dist,
    place_nodes,
    transmission_delays,
)


def _setup(n=12, seed=0, **kw):
    cfg = ChannelConfig(**kw)
    key = jax.random.PRNGKey(seed)
    pos = place_nodes(key, n, cfg)
    return cfg, key, pos


def test_placement_in_disk():
    cfg, key, pos = _setup(n=100)
    r = jnp.linalg.norm(pos, axis=-1)
    assert float(r.max()) <= cfg.radius + 1e-3


def test_delays_success_subset_of_tx():
    cfg, key, pos = _setup(message_bytes=51_640, gamma_max=10.0)
    tx = jnp.array([True] * 6 + [False] * 6)
    gamma, succ = transmission_delays(jax.random.fold_in(key, 1), pos, tx, cfg)
    # non-transmitting rows cannot succeed
    assert not bool(succ[6:].any())
    assert bool(succ[:6].any())  # some links work at these defaults


def test_delay_at_least_propagation():
    cfg, key, pos = _setup()
    tx = jnp.ones((12,), bool)
    gamma, _ = transmission_delays(jax.random.fold_in(key, 2), pos, tx, cfg)
    dist = pairwise_dist(pos)
    assert bool((gamma >= dist / LIGHTSPEED - 1e-9).all())


def test_tight_deadline_kills_links():
    cfg, key, pos = _setup(gamma_max=1e-9)
    tx = jnp.ones((12,), bool)
    _, succ = transmission_delays(jax.random.fold_in(key, 3), pos, tx, cfg)
    assert not bool(succ.any())


def test_bigger_message_slower():
    key = jax.random.PRNGKey(5)
    cfg_small = ChannelConfig(message_bytes=10_000)
    cfg_big = ChannelConfig(message_bytes=10_000_000)
    pos = place_nodes(key, 8, cfg_small)
    tx = jnp.ones((8,), bool)
    k = jax.random.fold_in(key, 1)
    g_small, _ = transmission_delays(k, pos, tx, cfg_small)
    g_big, _ = transmission_delays(k, pos, tx, cfg_big)
    assert bool((g_big >= g_small).all())
