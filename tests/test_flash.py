"""FlashAttention-2 custom-VJP path vs full attention: values AND grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_reduced
from repro.models.attention import flash_self_attention, full_attention, init_attention
from repro.models.flash import flash_attention


def _qkv(key, B=2, H=4, S=64, hd=16):
    ks = jax.random.split(key, 3)
    return tuple(jax.random.normal(k, (B, H, S, hd)) for k in ks)


def _ref(q, k, v, window=0):
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(hd)
    S, T = s.shape[-2], s.shape[-1]
    i = jnp.arange(S)[:, None]
    j = jnp.arange(T)[None, :]
    m = j <= i
    if window > 0:
        m = m & (j > i - window)
    s = jnp.where(m[None, None], s, -1e30)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)


@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("blocks", [(16, 16), (32, 16), (64, 64)])
def test_flash_forward(window, blocks):
    q, k, v = _qkv(jax.random.PRNGKey(0))
    bq, bk = blocks
    out = flash_attention(q, k, v, bq, bk, window)
    ref = _ref(q, k, v, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("window", [0, 24])
def test_flash_grads(window):
    q, k, v = _qkv(jax.random.PRNGKey(1))

    def f_flash(q, k, v):
        return (flash_attention(q, k, v, 16, 16, window) ** 2).sum()

    def f_ref(q, k, v):
        return (_ref(q, k, v, window) ** 2).sum()

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-4, rtol=3e-3, err_msg=name)


def test_flash_layer_matches_full_layer():
    cfg = get_reduced("yi-34b")  # GQA kv=2
    key = jax.random.PRNGKey(2)
    params = init_attention(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model))
    full = full_attention(params, x, cfg)
    flash = flash_self_attention(params, x, cfg, block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(full),
                               atol=2e-4, rtol=2e-3)


def test_flash_layer_grad_matches():
    cfg = get_reduced("stablelm-3b")
    key = jax.random.PRNGKey(3)
    params = init_attention(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 32, cfg.d_model))

    def loss_of(fn):
        return lambda p: (fn(p, x, cfg) ** 2).mean()

    g_full = jax.grad(loss_of(lambda p, x, c: full_attention(p, x, c)))(params)
    g_flash = jax.grad(
        loss_of(lambda p, x, c: flash_self_attention(p, x, c, block_q=16, block_kv=16))
    )(params)
    for (ka, a), (kb, b) in zip(sorted(g_full.items()), sorted(g_flash.items())):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-4, rtol=3e-3, err_msg=ka)
