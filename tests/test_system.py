"""End-to-end behaviour tests for the full DRACO system."""

import jax
import numpy as np
import pytest

from repro.core.baselines import init_baseline_state, run_baseline, eval_params
from repro.core.channel import ChannelConfig
from repro.core.protocol import DracoConfig, build_graph, init_state, run_windows
from repro.data.synthetic import federated_classification, make_mlp

# tier-2: end-to-end system runs (ROADMAP tier-1 runs -m "not slow")
pytestmark = pytest.mark.slow

N = 8


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    train, test = federated_classification(k1, N, input_dim=10, num_classes=5,
                                           per_client=200)
    params0, apply, loss, acc = make_mlp(k2, 10, (32, 32), 5)
    return train, test, params0, loss, acc


def _acc(params, acc, test):
    tx, ty = test
    return float(jax.vmap(lambda p: acc(p, tx, ty))(params).mean())


def test_draco_beats_or_matches_baselines_over_wireless(task):
    """Fig. 3 qualitative claim: DRACO is competitive with all four
    baselines under an unreliable wireless channel (cycle topology)."""
    train, test, params0, loss, acc = task
    chan = ChannelConfig(message_bytes=51_640, gamma_max=10.0)
    cfg = DracoConfig(num_clients=N, lr=0.1, local_batches=1, batch_size=32,
                      lambda_grad=0.5, lambda_tx=0.5, unify_period=25, psi=4,
                      topology="cycle", max_delay_windows=4, channel=chan)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.PRNGKey(1), cfg, params0)
    st = run_windows(st, cfg, q, adj, loss, train, 400)
    draco_acc = _acc(st.params, acc, test)

    base_accs = {}
    for m in ("sync-symm", "async-push"):
        bst = init_baseline_state(jax.random.PRNGKey(1), cfg, params0)
        bst = run_baseline(m, bst, cfg, loss, train, 120)
        base_accs[m] = _acc(eval_params(m, bst), acc, test)

    assert draco_acc > 0.5, draco_acc
    # competitive: within 10 points of the best baseline
    assert draco_acc > max(base_accs.values()) - 0.10, (draco_acc, base_accs)


def test_trainer_cli_end_to_end(tmp_path):
    """examples-grade driver: reduced arch trains and checkpoints resume."""
    from repro.launch.train import main as train_main

    ckpt = str(tmp_path / "ck")
    losses = train_main([
        "--arch", "qwen2-1.5b", "--reduced", "--steps", "12", "--clients", "4",
        "--seq", "32", "--batch-per-client", "1", "--unify-every", "6",
        "--ckpt-dir", ckpt, "--ckpt-every", "6", "--log-every", "6",
    ])
    assert np.isfinite(losses).all()
    # resume from step 12 checkpoint
    losses2 = train_main([
        "--arch", "qwen2-1.5b", "--reduced", "--steps", "14", "--clients", "4",
        "--seq", "32", "--batch-per-client", "1", "--unify-every", "6",
        "--ckpt-dir", ckpt, "--log-every", "2",
    ])
    assert len(losses2) == 2  # only steps 12->14 ran


def test_serve_cli_end_to_end():
    from repro.launch.serve import main as serve_main

    toks = serve_main(["--arch", "musicgen-large", "--reduced", "--batch", "2",
                       "--prompt-len", "4", "--new-tokens", "4"])
    assert toks.shape == (2, 4)
