import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import (
    classification_task,
    dirichlet_partition,
    federated_classification,
    lm_token_batches,
    make_mlp,
)


def test_classification_shapes():
    x, y, anchors = classification_task(jax.random.PRNGKey(0), 100, 8, 5)
    assert x.shape == (100, 8) and y.shape == (100,)
    assert int(y.max()) < 5 and anchors.shape == (5, 8)


def test_dirichlet_noniid():
    key = jax.random.PRNGKey(1)
    _, y, _ = classification_task(key, 5000, 4, 10)
    idx = dirichlet_partition(jax.random.fold_in(key, 1), y, num_clients=8,
                              num_classes=10, alpha=0.1, per_client=500)
    assert idx.shape == (8, 500)
    # low alpha -> clients have skewed class histograms
    hists = []
    for c in range(8):
        yc = np.asarray(y[idx[c]])
        h = np.bincount(yc, minlength=10) / 500
        hists.append(h)
    hists = np.stack(hists)
    assert hists.max(axis=1).mean() > 0.3  # concentrated


def test_federated_split_consistency():
    train, test = federated_classification(jax.random.PRNGKey(2), 4, 8, 5,
                                           per_client=64)
    xs, ys = train
    tx, ty = test
    assert xs.shape == (4, 64, 8) and ys.shape == (4, 64)
    # test drawn from the SAME anchors: a trained model generalizes (see
    # make_mlp usage in protocol tests); here just check label support
    assert int(ty.max()) < 5


def test_lm_batches():
    toks = lm_token_batches(jax.random.PRNGKey(3), 4, 8, 32, vocab=100)
    assert toks.shape == (4, 8, 32)
    assert int(toks.max()) < 100


def test_mlp_learns_centralized():
    key = jax.random.PRNGKey(4)
    train, test = federated_classification(key, 2, 8, 4, per_client=256)
    params, apply, loss, acc = make_mlp(jax.random.fold_in(key, 1), 8, (32,), 4)
    xs, ys = train
    x, y = xs.reshape(-1, 8), ys.reshape(-1)

    @jax.jit
    def step(p, k):
        i = jax.random.randint(k, (32,), 0, x.shape[0])
        return jax.tree_util.tree_map(
            lambda a, g: a - 0.2 * g, p, jax.grad(loss)(p, x[i], y[i]))

    for s in range(300):
        params = step(params, jax.random.fold_in(key, s))
    tx, ty = test
    assert float(acc(params, tx, ty)) > 0.7
