import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import (
    classification_task,
    dirichlet_partition,
    federated_classification,
    lm_token_batches,
    make_mlp,
)


def test_classification_shapes():
    x, y, anchors = classification_task(jax.random.PRNGKey(0), 100, 8, 5)
    assert x.shape == (100, 8) and y.shape == (100,)
    assert int(y.max()) < 5 and anchors.shape == (5, 8)


def test_dirichlet_noniid():
    key = jax.random.PRNGKey(1)
    _, y, _ = classification_task(key, 5000, 4, 10)
    idx = dirichlet_partition(jax.random.fold_in(key, 1), y, num_clients=8,
                              num_classes=10, alpha=0.1, per_client=500)
    assert idx.shape == (8, 500)
    # low alpha -> clients have skewed class histograms
    hists = []
    for c in range(8):
        yc = np.asarray(y[idx[c]])
        h = np.bincount(yc, minlength=10) / 500
        hists.append(h)
    hists = np.stack(hists)
    assert hists.max(axis=1).mean() > 0.3  # concentrated


def test_federated_split_consistency():
    train, test = federated_classification(jax.random.PRNGKey(2), 4, 8, 5,
                                           per_client=64)
    xs, ys = train
    tx, ty = test
    assert xs.shape == (4, 64, 8) and ys.shape == (4, 64)
    # test drawn from the SAME anchors: a trained model generalizes (see
    # make_mlp usage in protocol tests); here just check label support
    assert int(ty.max()) < 5


def test_lm_batches():
    toks = lm_token_batches(jax.random.PRNGKey(3), 4, 8, 32, vocab=100)
    assert toks.shape == (4, 8, 32)
    assert int(toks.max()) < 100


def test_mlp_learns_centralized():
    key = jax.random.PRNGKey(4)
    train, test = federated_classification(key, 2, 8, 4, per_client=256)
    params, apply, loss, acc = make_mlp(jax.random.fold_in(key, 1), 8, (32,), 4)
    xs, ys = train
    x, y = xs.reshape(-1, 8), ys.reshape(-1)

    @jax.jit
    def step(p, k):
        i = jax.random.randint(k, (32,), 0, x.shape[0])
        return jax.tree_util.tree_map(
            lambda a, g: a - 0.2 * g, p, jax.grad(loss)(p, x[i], y[i]))

    for s in range(300):
        params = step(params, jax.random.fold_in(key, s))
    tx, ty = test
    assert float(acc(params, tx, ty)) > 0.7


# ---------------------------------------------------------------------------
# dirichlet_partition contracts (PR 5): index bounds + alpha extremes
# ---------------------------------------------------------------------------


def test_dirichlet_partition_index_bounds():
    """Every sampled index addresses the pool: 0 <= idx < n_samples, for
    several client counts and alphas (with-replacement categorical draws
    must never escape the dataset)."""
    key = jax.random.PRNGKey(10)
    n_samples = 777  # deliberately not a round number
    _, y, _ = classification_task(key, n_samples, 4, 6)
    for alpha in (0.05, 100.0):
        for num_clients in (1, 16):
            idx = dirichlet_partition(jax.random.fold_in(key, hash((alpha, num_clients)) % 2**31),
                                      y, num_clients=num_clients,
                                      num_classes=6, alpha=alpha,
                                      per_client=200)
            arr = np.asarray(idx)
            assert arr.shape == (num_clients, 200)
            assert arr.min() >= 0 and arr.max() < n_samples
            assert np.issubdtype(arr.dtype, np.integer)


def _client_class_hists(y, idx, num_classes):
    return np.stack([
        np.bincount(np.asarray(y[c]), minlength=num_classes) / c.shape[0]
        for c in np.asarray(idx)
    ])


def test_dirichlet_alpha_to_zero_collapses_to_single_class():
    """alpha -> 0: each client's Dirichlet draw concentrates on one
    class, so its shard is (near-)pure — max class share -> 1."""
    key = jax.random.PRNGKey(11)
    _, y, _ = classification_task(key, 8000, 4, 8)
    idx = dirichlet_partition(jax.random.fold_in(key, 1), y, num_clients=12,
                              num_classes=8, alpha=1e-3, per_client=400)
    hists = _client_class_hists(y, idx, 8)
    # most clients are pure; the occasional draw splits across two
    # classes (still a valid Dirichlet sample), so pin mean + floor
    assert hists.max(axis=1).mean() > 0.9
    assert hists.max(axis=1).min() > 0.5
    # monotone in alpha: far more concentrated than the alpha=0.5 regime
    idx_mild = dirichlet_partition(jax.random.fold_in(key, 3), y,
                                   num_clients=12, num_classes=8,
                                   alpha=0.5, per_client=400)
    assert (hists.max(axis=1).mean()
            > _client_class_hists(y, idx_mild, 8).max(axis=1).mean())


def test_dirichlet_alpha_to_inf_approaches_uniform():
    """alpha -> inf: draws concentrate on the uniform simplex center, so
    shards approach the pool's class distribution (IID split)."""
    key = jax.random.PRNGKey(12)
    _, y, _ = classification_task(key, 5000, 4, 8)
    idx = dirichlet_partition(jax.random.fold_in(key, 2), y, num_clients=8,
                              num_classes=8, alpha=1e4, per_client=1000)
    hists = _client_class_hists(y, idx, 8)
    # every class present on every client, shares near 1/8
    assert hists.min() > 0.0
    np.testing.assert_allclose(hists, 1.0 / 8, atol=0.05)
    # and far less concentrated than a skewed split
    assert hists.max(axis=1).mean() < 0.2


def test_classification_task_anchor_reuse_determinism():
    """Passing anchors= back in (a) skips the anchor draw deterministically
    — same key, same anchors -> bitwise-identical samples — and (b)
    generates from the *given* mixture: the paper's train/test split
    draws both sets from one anchor family."""
    key = jax.random.PRNGKey(13)
    x1, y1, anchors = classification_task(key, 500, 8, 5)
    # reuse: identical draw when anchors are supplied explicitly
    x2, y2, anchors2 = classification_task(key, 500, 8, 5, anchors=anchors)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(anchors), np.asarray(anchors2))
    # foreign anchors change the samples but not the label stream
    other = jnp.asarray(np.asarray(anchors)[::-1].copy())
    x3, y3, _ = classification_task(key, 500, 8, 5, anchors=other)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y3))
    assert not np.array_equal(np.asarray(x1), np.asarray(x3))
    # low noise: samples cluster on their class anchor
    x4, y4, _ = classification_task(jax.random.fold_in(key, 1), 500, 8, 5,
                                    noise=1e-3, anchors=anchors)
    d = np.linalg.norm(np.asarray(x4) - np.asarray(anchors)[np.asarray(y4)],
                       axis=1)
    assert d.max() < 0.1
