import os
import re
import sys

# Keep the default 1-CPU-device view for smoke tests; mesh/dry-run tests
# spawn subprocesses that set XLA_FLAGS themselves (per project policy).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


# ROADMAP tiering: battery files (parity/mesh/theory/property/system/
# dryrun) and hypothesis tests must be marked slow, or tier-1's ~2-min
# budget erodes as the suite grows. The static side of this check is
# repro.analysis's MARKER-DISCIPLINE rule; this hook enforces it at
# collection time too (it sees dynamically generated tests the AST
# can't).
_BATTERY_FILE = re.compile(r"test_.*(parity|mesh|theory|property|system|dryrun)")


def pytest_collection_modifyitems(config, items):
    offenders = []
    for item in items:
        if item.get_closest_marker("slow") is not None:
            continue
        fname = os.path.basename(str(item.fspath))
        if _BATTERY_FILE.match(fname):
            offenders.append(f"{item.nodeid} (battery file {fname})")
        elif item.get_closest_marker("hypothesis") is not None:
            offenders.append(f"{item.nodeid} (hypothesis test)")
    if offenders:
        raise pytest.UsageError(
            "tests missing @pytest.mark.slow (ROADMAP tiering):\n  "
            + "\n  ".join(offenders))
