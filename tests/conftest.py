import os
import sys

# Keep the default 1-CPU-device view for smoke tests; mesh/dry-run tests
# spawn subprocesses that set XLA_FLAGS themselves (per project policy).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
