import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.axes import constrain
from repro.sharding.specs import filter_divisible, param_spec, tree_param_specs


class FakeMesh:
    shape = {"data": 4, "model": 8, "pod": 2}


def test_param_spec_rules():
    assert param_spec("groups/0:attn/attn/wq", (3, 128, 256)) == P(None, None, "model")
    assert param_spec("groups/0:attn/attn/wo", (3, 256, 128)) == P(None, "model", None)
    assert param_spec("embed", (1024, 64)) == P("model", None)
    assert param_spec("groups/0:moe/moe/experts_gate", (2, 8, 16, 32)) == P(
        None, "model", None, None)
    assert param_spec("final_norm", (64,)) == P(None)


def test_param_spec_prefix():
    s = param_spec("groups/0:mlp/mlp/w_up", (16, 3, 64, 128), prefix=("data",))
    assert s == P("data", None, None, "model")


def test_filter_divisible():
    m = FakeMesh()
    assert filter_divisible(P("model", None), (64, 3), m) == P("model", None)
    assert filter_divisible(P("model", None), (63, 3), m) == P(None, None)
    assert filter_divisible(P(("pod", "data"), "model"), (8, 16), m) == P(
        ("pod", "data"), "model")
    assert filter_divisible(P(("pod", "data"), None), (7, 16), m) == P(None, None)


def test_tree_param_specs_structure():
    tree = {"embed": jnp.zeros((16, 8)), "g": {"wq": jnp.zeros((2, 8, 16))}}
    specs = tree_param_specs(tree)
    assert specs["embed"] == P("model", None)
    assert specs["g"]["wq"] == P(None, None, "model")


def test_constrain_noop_without_rules():
    x = jnp.zeros((4, 8))
    y = constrain(x, "batch", "ff")
    assert y is x
