"""The task layer: registry, flat-plane optimizer state, and parity.

The acceptance bar for PR 5 (mirrors tests/test_api.py's role for the
API redesign): the default ``linear-softmax`` + ``sgd(constant)`` task
must be **bit-for-bit** the pre-task bare-loss path for DRACO and all
four baselines, while the new workloads (mlp / small-cnn / tiny-lm) and
local optimizers (momentum / adamw) run jitted end-to-end through both
`simulate` and `simulate_sweep` with their optimizer state on the flat
plane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import get_algorithm, make_context, simulate, simulate_sweep, steps_for_budget
from repro.core.baselines import BASELINES
from repro.core.protocol import (
    DracoConfig,
    build_graph,
    init_state,
    init_state_legacy,
    run_windows,
    run_windows_legacy,
)
from repro.tasks import as_task, get_task, is_task, list_tasks, opt_width
from repro.tasks.base import loss_of

N = 5
ALL_METHODS = ("draco",) + tuple(BASELINES)
ZOO = ("linear-softmax", "mlp", "small-cnn", "tiny-lm")


def _cfg(**kw):
    base = dict(num_clients=N, lr=0.1, local_batches=2, batch_size=8,
                lambda_grad=0.8, lambda_tx=0.8, unify_period=10, psi=2,
                topology="complete", max_delay_windows=3, channel=None)
    base.update(kw)
    return DracoConfig(**base)


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def default_task():
    """The default workload + its explicitly-built (params, data)."""
    task = get_task("linear-softmax", input_dim=6, num_classes=3,
                    per_client=64)
    params0, train, test = task.setup(jax.random.PRNGKey(0), N)
    return task, params0, train, test


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_resolves_every_task():
    names = list_tasks()
    for name in ZOO:
        assert name in names
        t = get_task(name)
        assert is_task(t) and t.name == name
        # singleton per knob set: stable static jit keys
        assert get_task(name) is t
    assert get_task("mlp", hidden=(8,)) is get_task("mlp", hidden=(8,))
    assert get_task("mlp", hidden=(8,)) is not get_task("mlp")
    with pytest.raises(KeyError):
        get_task("no-such-task")
    with pytest.raises(KeyError):
        get_task("mlp").with_optimizer("no-such-optimizer")


def test_legacy_loss_shim():
    """Bare callables wrap into a stable plain-SGD task; accessors agree."""
    loss = lambda p, x, y: jnp.sum(p * 0.0)
    t = as_task(loss)
    assert is_task(t) and t.loss_fn is loss and as_task(t) is t
    assert as_task(loss) is t  # cached: stable identity across calls
    assert loss_of(t) is loss and loss_of(loss) is loss
    assert opt_width(loss, {"w": jnp.zeros((3,))}) == 0
    with pytest.raises(NotImplementedError):
        t.make_data(jax.random.PRNGKey(0), 2)


def test_opt_width_layouts(default_task):
    """sgd -> 0, momentum -> Dflat, adamw -> 2*Dflat + 1 (m, v, and its
    per-client bias-correction counter) on the flat plane."""
    task, params0, _, _ = default_task
    dflat = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(params0))
    assert opt_width(task, params0) == 0
    assert opt_width(task.with_optimizer("momentum"), params0) == dflat
    # adamw: m + v planes + its per-client bias-correction counter
    assert opt_width(task.with_optimizer("adamw"), params0) == 2 * dflat + 1
    ctx = make_context(_cfg(), task=task.with_optimizer("adamw"),
                       params0=params0)
    assert ctx.flat_spec.opt_dim == 2 * dflat + 1
    assert ctx.flat_spec.dim == dflat


# ---------------------------------------------------------------------------
# Bit-for-bit parity: default task == pre-refactor bare-loss path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", [
    "draco",
    "sync-symm",
    pytest.param("sync-push", marks=pytest.mark.slow),
    pytest.param("async-symm", marks=pytest.mark.slow),
    pytest.param("async-push", marks=pytest.mark.slow),
])
def test_default_task_parity_bitwise(method, default_task):
    """`simulate(m, task="linear-softmax")` with sgd(constant) is
    bit-for-bit the bare-`loss_fn` path for DRACO + all 4 baselines —
    the task layer is a refactor, not a fork."""
    task, params0, train, test = default_task
    cfg = _cfg(topology="cycle")
    key = jax.random.PRNGKey(11)
    old, old_tr = simulate(method, cfg, params0, task.loss_fn, train, 9,
                           key=key, eval_every=4, eval_fn=task.eval_fn,
                           eval_data=test)
    new, new_tr = simulate(method, cfg, params0, data=train, task=task,
                           num_steps=9, key=key, eval_every=4, eval_data=test)
    _assert_trees_equal(old.params, new.params)
    _assert_trees_equal(old_tr.metrics["accuracy"],
                        new_tr.metrics["accuracy"])
    _assert_trees_equal(get_algorithm(method).eval_params(old),
                        get_algorithm(method).eval_params(new))
    assert new.opt_state.shape == (N, 0)  # plain SGD: empty optimizer plane


def test_default_task_parity_wireless_psi(default_task):
    """Same equality through the wireless channel + Psi cap + unification
    (the full DRACO machinery), via the legacy run_windows entry."""
    from repro.core.channel import ChannelConfig

    task, params0, train, _ = default_task
    cfg = _cfg(channel=ChannelConfig(message_bytes=51_640, gamma_max=10.0),
               max_delay_windows=4)
    key = jax.random.PRNGKey(7)
    q, adj = build_graph(cfg)
    bare = run_windows(init_state(key, cfg, params0), cfg, q, adj,
                       task.loss_fn, train, 11)
    tsk = run_windows(init_state(key, cfg, params0, task=task), cfg, q, adj,
                      task, train, 11)
    _assert_trees_equal(bare.params, tsk.params)
    _assert_trees_equal(bare.pending, tsk.pending)
    _assert_trees_equal(bare.buffer, tsk.buffer)
    np.testing.assert_array_equal(np.asarray(bare.total_accept),
                                  np.asarray(tsk.total_accept))


@pytest.mark.slow
@pytest.mark.parametrize("opt", ["momentum", "adamw"])
def test_fused_vs_legacy_engine_with_optimizer(opt, default_task):
    """Both gossip engines agree bit-for-bit on a *stateful* optimizer
    task: the optimizer plane is engine-independent."""
    task, params0, train, _ = default_task
    task = task.with_optimizer(opt)
    cfg = _cfg(max_delay_windows=4)
    q, adj = build_graph(cfg)
    key = jax.random.PRNGKey(13)
    sf = run_windows(init_state(key, cfg, params0, task=task), cfg, q, adj,
                     task, train, 9)
    sl = run_windows_legacy(init_state_legacy(key, cfg, params0, task=task),
                            cfg, q, adj, task, train, 9)
    _assert_trees_equal(sf.params, sl.params)
    np.testing.assert_array_equal(np.asarray(sf.opt_state),
                                  np.asarray(sl.opt_state))
    assert np.abs(np.asarray(sf.opt_state)).sum() > 0


# ---------------------------------------------------------------------------
# New tasks x optimizers, end-to-end jitted
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,opt", [
    ("mlp", "momentum"),
    pytest.param("small-cnn", "adamw", marks=pytest.mark.slow),
    pytest.param("tiny-lm", "adamw", marks=pytest.mark.slow),
])
def test_task_zoo_end_to_end_simulate(name, opt):
    """Every new workload runs jitted through simulate() with optimizer
    state on the flat plane, producing finite task-named metrics."""
    task = get_task(name, optimizer=opt)
    cfg = _cfg(lr=0.01)
    st, trace = simulate("draco", cfg, task=task, num_steps=6,
                         key=jax.random.PRNGKey(1), eval_every=3)
    assert task.metric_name in trace.metrics
    assert np.isfinite(trace.metrics[task.metric_name]).all()
    # stateful optimizer: the flat plane actually carries state
    p0 = task.init_params(jax.random.PRNGKey(0))
    assert st.opt_state.shape == (N, opt_width(task, p0))
    assert np.abs(np.asarray(st.opt_state)).sum() > 0
    for leaf in jax.tree_util.tree_leaves(st.params):
        assert bool(jnp.isfinite(leaf).all())


@pytest.mark.parametrize("method", BASELINES[:2])
def test_task_zoo_baselines(method):
    """Baselines consume tasks through the same local_step dispatcher."""
    task = get_task("mlp", optimizer="momentum")
    st, trace = simulate(method, _cfg(lr=0.01), task=task, num_steps=4,
                         key=jax.random.PRNGKey(2), eval_every=2)
    assert np.isfinite(trace.metrics["accuracy"]).all()
    assert np.abs(np.asarray(st.opt_state)).sum() > 0


@pytest.mark.slow
def test_momentum_differs_from_sgd():
    """The optimizer axis is real: momentum != plain SGD trajectories."""
    cfg = _cfg(lr=0.05)
    key = jax.random.PRNGKey(5)
    t_sgd = get_task("mlp")
    t_mom = get_task("mlp", optimizer="momentum")
    s1, _ = simulate("draco", cfg, task=t_sgd, num_steps=5, key=key)
    s2, _ = simulate("draco", cfg, task=t_mom, num_steps=5, key=key)
    flat = lambda s: np.concatenate(
        [np.asarray(l).ravel() for l in jax.tree_util.tree_leaves(s.params)])
    assert not np.array_equal(flat(s1), flat(s2))


def test_perplexity_metric_and_improvement():
    """tiny-lm reports perplexity and training moves it (finite, >0)."""
    task = get_task("tiny-lm", optimizer="adamw")
    cfg = _cfg(lr=0.01, lambda_grad=3.0, unify_period=0, psi=0)
    _, trace = simulate("draco", cfg, task=task, num_steps=8,
                        key=jax.random.PRNGKey(3), eval_every=4)
    ppl = trace.metrics["perplexity"]
    assert "accuracy" not in trace.metrics
    assert (ppl > 0).all() and np.isfinite(ppl).all()


@pytest.mark.slow
def test_task_sweep_lr_axis_with_adamw():
    """simulate_sweep: lr grid x seeds on an adamw task — the optimizer
    hyperparameter rides the traced config axis, state on the flat
    plane, and distinct lrs give distinct rows."""
    task = get_task("mlp", optimizer="adamw")
    base = _cfg(lr=0.001)
    grid = [base, base.replace(lr=0.1)]
    finals, trace = simulate_sweep("draco", grid, task=task, num_steps=5,
                                   key=jax.random.PRNGKey(4), num_seeds=2,
                                   eval_every=5)
    assert trace.metrics["accuracy"].shape == (2, 2, 1)
    p0 = task.init_params(jax.random.PRNGKey(0))
    assert finals.opt_state.shape == (2, 2, N, opt_width(task, p0))
    # the lr override reached the schedule: rows differ
    assert not np.array_equal(np.asarray(finals.opt_state[0]),
                              np.asarray(finals.opt_state[1]))


@pytest.mark.slow
def test_task_sweep_seed_row_matches_solo():
    """Sweep seed-row k with a task == solo simulate(key=keys[k])."""
    task = get_task("tiny-lm", optimizer="momentum")
    cfg = _cfg(lr=0.01)
    keys = jax.random.split(jax.random.PRNGKey(6), 2)
    finals, tr = simulate_sweep("draco", cfg, task=task, num_steps=4,
                                keys=keys, eval_every=2)
    solo, solo_tr = simulate("draco", cfg, task=task, num_steps=4,
                             key=keys[1], eval_every=2)
    np.testing.assert_array_equal(np.asarray(finals.opt_state[0, 1]),
                                  np.asarray(solo.opt_state))
    np.testing.assert_array_equal(np.asarray(tr.metrics["perplexity"][0, 1]),
                                  np.asarray(solo_tr.metrics["perplexity"]))


def test_sweep_rejects_lr_blind_task(default_task):
    """A task that does not declare lr sweepable is rejected (its rows
    would silently be identical)."""
    task, params0, train, _ = default_task
    import dataclasses

    frozen_lr = dataclasses.replace(task, sweepable=())
    base = _cfg()
    with pytest.raises(ValueError, match="sweepable"):
        simulate_sweep("draco", [base, base.replace(lr=0.01)], params0,
                       data=train, task=frozen_lr, num_steps=2,
                       key=jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Compute matching: budget equalizes FLOPs through task.grad_cost
# ---------------------------------------------------------------------------


def test_steps_for_budget_equalizes_flops():
    """With a task, budget-matched runs equalize expected FLOPs across
    algorithms: steps * grads_per_step * grad_cost ~= budget for every
    method (within one step of rounding)."""
    cfg = _cfg(lambda_grad=0.1)
    for name in ZOO:
        task = get_task(name)
        budget = 400.0 * task.grad_cost  # FLOP units
        for method in ALL_METHODS:
            rate = get_algorithm(method).grads_per_step(cfg)
            steps = steps_for_budget(method, cfg, budget, task=task)
            flops = steps * rate * task.grad_cost
            assert abs(flops - budget) <= rate * task.grad_cost + 1e-6, (
                name, method)


def test_steps_for_budget_task_scales_with_model_cost():
    """A costlier model gets fewer budget-matched steps; the legacy
    no-task call keeps uniform pricing."""
    cfg = _cfg()
    lin, lm = get_task("linear-softmax"), get_task("tiny-lm")
    assert lm.grad_cost > lin.grad_cost
    budget = 100.0 * lm.grad_cost
    s_lin = steps_for_budget("sync-symm", cfg, budget, task=lin)
    s_lm = steps_for_budget("sync-symm", cfg, budget, task=lm)
    assert s_lm < s_lin
    assert steps_for_budget("sync-symm", cfg, 50.0) == 50  # legacy unchanged


def test_task_in_legacy_loss_position(default_task):
    """A Task passed where a loss callable used to go is promoted to the
    task path in BOTH entry points: builders fill params0/data, task_key
    is accepted, and the result is bitwise the explicit-task call."""
    task, params0, train, test = default_task
    cfg = _cfg()
    key = jax.random.PRNGKey(21)
    st_pos, _ = simulate("draco", cfg, None, task, num_steps=2, key=key)
    st_kw, _ = simulate("draco", cfg, params0, data=train, task=task,
                        num_steps=2, key=key)
    _assert_trees_equal(st_pos.params, st_kw.params)
    keys = jax.random.split(key, 1)
    fin, _ = simulate_sweep("draco", cfg, None, task, num_steps=2, keys=keys,
                            task_key=jax.random.PRNGKey(0))
    solo, _ = simulate("draco", cfg, None, task, num_steps=2, key=keys[0])
    _assert_trees_equal(
        jax.tree_util.tree_map(lambda l: l[0, 0], fin.params), solo.params)


def test_optimizer_spellings_build_equal_tasks():
    """get_task(name, optimizer=X) == get_task(name).with_optimizer(X):
    both derive from one cached base, sharing loss/eval/data closures —
    one static jit key, and either spelling passes the ctx-task check."""
    for name in ZOO:
        a = get_task(name, optimizer="adamw")
        b = get_task(name).with_optimizer("adamw")
        assert a == b and hash(a) == hash(b), name
        assert a.loss_fn is b.loss_fn and a.make_data is b.make_data
    # kwargs follow their family: keeping the optimizer keeps its knobs
    m = get_task("mlp", optimizer="momentum", opt_kwargs={"beta": 0.99})
    m2 = m.with_optimizer("momentum", schedule="cosine",
                          schedule_kwargs={"total_steps": 600})
    assert dict(m2.opt_kwargs)["beta"] == 0.99
    # ...and switching families clears them
    assert m.with_optimizer("adamw").opt_kwargs == ()


def test_adamw_bias_correction_is_per_client():
    """A client whose first gradient event fires late still gets the
    full first-step AdamW correction: the counter lives in the opt
    state, not the global window clock."""
    from repro import optim

    opt = optim.adamw(0.1)
    p = {"x": jnp.ones(3)}
    g = {"x": jnp.full((3,), 0.5)}
    s0 = opt.init(p)
    # first absorbed update at protocol step 100 == at protocol step 0
    u_late, s_late = opt.update(g, s0, p, jnp.asarray(100))
    u_early, _ = opt.update(g, s0, p, jnp.asarray(0))
    np.testing.assert_array_equal(np.asarray(u_late["x"]),
                                  np.asarray(u_early["x"]))
    assert float(s_late["t"]) == 1.0
    # first-step magnitude ~ lr (mhat/sqrt(vhat) = sign(g)), not (1-b1)*lr
    np.testing.assert_allclose(np.asarray(u_late["x"]), -0.1, rtol=1e-3)


def test_builder_kwargs_accept_dicts_and_lists():
    """Registry cache keys canonicalize dict/list knobs (the documented
    opt_kwargs/hidden spellings must not crash on hashing)."""
    a = get_task("mlp", hidden=[8, 8], optimizer="momentum",
                 opt_kwargs={"beta": 0.95})
    b = get_task("mlp", hidden=(8, 8), optimizer="momentum",
                 opt_kwargs={"beta": 0.95})
    assert a is b and dict(a.opt_kwargs)["beta"] == 0.95


def test_with_optimizer_schedule_kwargs(default_task):
    """Switching schedule families threads their kwargs (cosine needs
    total_steps) and clears stale kwargs on the way back."""
    task, _, _, _ = default_task
    cos = task.with_optimizer("adamw", schedule="cosine",
                              schedule_kwargs={"total_steps": 100})
    cos.make_optimizer(0.01)  # would raise without total_steps threading
    # restating the current family keeps its kwargs...
    same = cos.with_optimizer("momentum", schedule="cosine")
    assert dict(same.schedule_kwargs)["total_steps"] == 100
    same.make_optimizer(0.01)
    # ...and switching families clears them
    cos.with_optimizer("sgd", schedule="constant").make_optimizer(0.01)
    with pytest.raises(TypeError):
        # family changed without kwargs: cosine still requires total_steps
        task.with_optimizer("adamw", schedule="cosine").make_optimizer(0.01)


def test_prebuilt_ctx_skips_task_builders_and_accepts_equal_tasks(
        default_task):
    """A prebuilt ctx supplies the shards: the task's dataset builder
    must not run again (regenerating would also inject an eval set from
    *different* mixture anchors), and the ctx-vs-argument workload check
    compares by equality — two `with_optimizer()` copies are the same
    static jit key, not a conflict."""
    import dataclasses

    task, params0, train, test = default_task
    cfg = _cfg()
    t1 = task.with_optimizer("momentum")
    t2 = task.with_optimizer("momentum")
    assert t1 is not t2 and t1 == t2
    calls = {"n": 0}
    orig = t1.make_data

    def counting_make_data(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    spy = dataclasses.replace(t1, make_data=counting_make_data)
    ctx = make_context(cfg, task=spy, data=train, params0=params0)
    st, tr = simulate("draco", cfg, task=spy, num_steps=2,
                      key=jax.random.PRNGKey(0), ctx=ctx, eval_every=2,
                      eval_data=test)
    assert calls["n"] == 0 and "accuracy" in tr.metrics
    simulate_sweep("draco", cfg, task=spy, num_steps=1,
                   key=jax.random.PRNGKey(0), num_seeds=1, ctx=ctx)
    assert calls["n"] == 0
    # equal-but-distinct task instances pass the ctx consistency check
    ctx_eq = make_context(cfg, task=t1, data=train, params0=params0)
    st2, _ = simulate("draco", cfg, params0, data=train, task=t2,
                      num_steps=1, key=jax.random.PRNGKey(0), ctx=ctx_eq)
    assert int(st2.window_idx) == 1


def test_task_conflicts_rejected(default_task):
    task, params0, train, _ = default_task
    other_loss = lambda p, x, y: 0.0
    with pytest.raises(ValueError, match="not both"):
        simulate("draco", _cfg(), params0, other_loss, train, 1,
                 task=task, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="task_key"):
        simulate("draco", _cfg(), params0, task.loss_fn, train, 1,
                 task_key=jax.random.PRNGKey(0), key=jax.random.PRNGKey(0))
    ctx = make_context(_cfg(), task=task, data=train, params0=params0)
    with pytest.raises(ValueError, match="ctx.task"):
        simulate("draco", _cfg(), params0, data=train,
                 task=get_task("mlp"), num_steps=1,
                 key=jax.random.PRNGKey(0), ctx=ctx)
