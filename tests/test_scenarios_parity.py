"""Scenario engine vs the frozen-graph simulator: bit-for-bit + liveness.

The acceptance bar for the scenario engine (same guarantee style as
tests/test_protocol_parity.py for the fused gossip engine): a `static`
scenario run must equal the scenario-less `simulate()` path **exactly**
— every observable of the final state, for DRACO and all four baselines
— because the static schedule is the same graph built by the same calls,
and step functions receive None positions/rates, i.e. the frozen code
path. Anything weaker than `assert_array_equal` would let a schedule-
indexing bug hide behind "close enough".

The non-static generators (`markov-edge-flip`, `random-waypoint`,
`straggler-profile`) are exercised end-to-end under jit for every
method, including schedule wrap-around (more steps than the ring
period), with row-stochasticity checked at every scheduled step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import get_algorithm, make_context, simulate
from repro.core.baselines import BASELINES
from repro.core.channel import ChannelConfig
from repro.core.protocol import DracoConfig
from repro.core.topology import is_row_stochastic
from repro.data.synthetic import federated_classification, make_mlp

# tier-2: scenario parity battery (ROADMAP tier-1 runs -m "not slow")
pytestmark = pytest.mark.slow

N = 5
DYNAMIC = ("markov-edge-flip", "random-waypoint", "straggler-profile")
CHANNEL = ChannelConfig(message_bytes=51_640, gamma_max=10.0)


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    train, test = federated_classification(k1, N, input_dim=6, num_classes=3,
                                           per_client=64)
    params0, apply, loss, acc = make_mlp(k2, 6, (8,), 3)
    return train, test, params0, loss, acc


def _cfg(**kw):
    base = dict(num_clients=N, lr=0.1, local_batches=1, batch_size=8,
                lambda_grad=0.8, lambda_tx=0.8, unify_period=10, psi=2,
                topology="complete", max_delay_windows=3, channel=None)
    base.update(kw)
    return DracoConfig(**base)


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_static_parity_draco_bitwise(task):
    """static scenario == frozen path for DRACO, every state observable,
    with the wireless channel + Psi cap + unification all active."""
    train, test, params0, loss, acc = task
    cfg = _cfg(channel=CHANNEL)
    key = jax.random.PRNGKey(7)
    frozen, tr_f = simulate("draco", cfg, params0, loss, train, 12, key=key,
                            eval_every=4, eval_fn=acc, eval_data=test)
    static, tr_s = simulate("draco", cfg, params0, loss, train, 12, key=key,
                            eval_every=4, eval_fn=acc, eval_data=test,
                            scenario="static")
    _assert_trees_equal(frozen.params, static.params)
    np.testing.assert_array_equal(np.asarray(frozen.pending),
                                  np.asarray(static.pending))
    np.testing.assert_array_equal(np.asarray(frozen.buffer),
                                  np.asarray(static.buffer))
    np.testing.assert_array_equal(np.asarray(frozen.w_ring),
                                  np.asarray(static.w_ring))
    np.testing.assert_array_equal(np.asarray(frozen.delay_ring),
                                  np.asarray(static.delay_ring))
    np.testing.assert_array_equal(np.asarray(frozen.accept_count),
                                  np.asarray(static.accept_count))
    np.testing.assert_array_equal(np.asarray(frozen.total_accept),
                                  np.asarray(static.total_accept))
    np.testing.assert_array_equal(np.asarray(frozen.positions),
                                  np.asarray(static.positions))
    np.testing.assert_array_equal(np.asarray(frozen.key),
                                  np.asarray(static.key))
    assert int(frozen.window_idx) == int(static.window_idx) == 12
    for k in tr_f.metrics:
        np.testing.assert_array_equal(tr_f.metrics[k], tr_s.metrics[k])


@pytest.mark.parametrize("method", BASELINES)
def test_static_parity_baselines_bitwise(method, task):
    """static scenario == frozen path for every baseline (params,
    push weights, RNG stream)."""
    train, _, params0, loss, _ = task
    cfg = _cfg(topology="cycle")
    key = jax.random.PRNGKey(11)
    frozen, _ = simulate(method, cfg, params0, loss, train, 8, key=key)
    static, _ = simulate(method, cfg, params0, loss, train, 8, key=key,
                         scenario="static")
    _assert_trees_equal(frozen.params, static.params)
    np.testing.assert_array_equal(np.asarray(frozen.push_weight),
                                  np.asarray(static.push_weight))
    np.testing.assert_array_equal(np.asarray(frozen.key),
                                  np.asarray(static.key))
    assert int(frozen.round_idx) == int(static.round_idx) == 8
    _assert_trees_equal(get_algorithm(method).eval_params(frozen),
                        get_algorithm(method).eval_params(static))


def test_static_parity_random_topology_same_key(task):
    """With a random base topology the parity holds iff the scenario
    generator consumes the same graph key as the frozen path."""
    train, _, params0, loss, _ = task
    cfg = _cfg(topology="erdos", channel=CHANNEL)
    key, gkey = jax.random.PRNGKey(3), jax.random.PRNGKey(21)
    frozen, _ = simulate("draco", cfg, params0, loss, train, 6, key=key,
                         graph_key=gkey)
    static, _ = simulate("draco", cfg, params0, loss, train, 6, key=key,
                         graph_key=gkey, scenario="static")
    _assert_trees_equal(frozen.params, static.params)


@pytest.mark.parametrize("scenario", DYNAMIC)
@pytest.mark.parametrize("method", ("draco",) + BASELINES)
def test_dynamic_scenarios_run_under_jit(scenario, method, task):
    """Every non-static generator drives every method end-to-end inside
    the compiled scan, past the ring period (wrap-around), with finite
    params and an advanced step counter."""
    train, _, params0, loss, _ = task
    cfg = _cfg(channel=CHANNEL if scenario == "random-waypoint" else None)
    steps, period = 7, 4  # steps > period: exercises index wrap-around
    st, _ = simulate(method, cfg, params0, loss, train, steps,
                     key=jax.random.PRNGKey(5), scenario=scenario,
                     scenario_kwargs={"steps": period})
    for leaf in jax.tree_util.tree_leaves(st.params):
        assert bool(jnp.isfinite(leaf).all()), (scenario, method)
    idx = st.window_idx if method == "draco" else st.round_idx
    assert int(idx) == steps


def test_dynamic_schedule_rows_row_stochastic(task):
    """The exact Q rows a dynamic run consumes (step t -> ring row
    t % period) are row-stochastic — the in-scan view, not just the
    generator's output."""
    train, _, params0, loss, _ = task
    cfg = _cfg()
    ctx = make_context(cfg, loss, train, scenario="markov-edge-flip",
                       scenario_key=jax.random.PRNGKey(9),
                       scenario_kwargs={"steps": 5, "churn": 0.4})
    for t in range(11):
        snap = ctx.schedule.at(t)
        assert is_row_stochastic(snap.q), f"step {t}"
        np.testing.assert_array_equal(
            np.asarray(snap.q), np.asarray(ctx.schedule.q[t % 5]))


def test_mobility_positions_tracked_in_state(task):
    """random-waypoint: the state's positions after step k equal the
    schedule's row for step k-1 (the last window's geometry)."""
    train, _, params0, loss, _ = task
    cfg = _cfg(channel=CHANNEL)
    ctx = make_context(cfg, loss, train, scenario="random-waypoint",
                       scenario_key=jax.random.PRNGKey(13),
                       scenario_kwargs={"steps": 6, "speed": 40.0})
    st, _ = simulate("draco", cfg, params0, loss, train, 4,
                     key=jax.random.PRNGKey(1), ctx=ctx)
    np.testing.assert_array_equal(np.asarray(st.positions),
                                  np.asarray(ctx.schedule.positions[3]))


def test_straggler_profile_starves_gradients(task):
    """A fully-stalled compute ring (rate 0 via 100% stragglers at
    infinite slowdown) produces zero pending mass in DRACO — the
    decoupled computation schedule is really being modulated."""
    train, _, params0, loss, _ = task
    cfg = _cfg(lambda_tx=0.0, unify_period=0)  # pending only accumulates
    from repro.scenarios import make_schedule

    sched = make_schedule("straggler-profile", cfg,
                          key=jax.random.PRNGKey(2), steps=4,
                          straggler_frac=1.0, slowdown=1e12)
    ctx = make_context(cfg, loss, train)
    stalled, _ = simulate("draco", cfg, params0, loss, train, 5,
                          key=jax.random.PRNGKey(4),
                          ctx=ctx.replace(schedule=sched))
    live, _ = simulate("draco", cfg, params0, loss, train, 5,
                       key=jax.random.PRNGKey(4), ctx=ctx)
    assert float(jnp.abs(stalled.pending).sum()) == 0.0
    assert float(jnp.abs(live.pending).sum()) > 0.0


def test_scenario_with_prebuilt_ctx_rejected(task):
    train, _, params0, loss, _ = task
    cfg = _cfg()
    ctx = make_context(cfg, loss, train)
    with pytest.raises(ValueError, match="scenario"):
        simulate("draco", cfg, params0, loss, train, 2,
                 key=jax.random.PRNGKey(0), ctx=ctx, scenario="static")
