"""Mesh integration tests in subprocesses (XLA device count must be set
before jax initializes, so these run out-of-process on an 8-device CPU
mesh with reduced configs). Validates the full launch path: shardings,
DRACO window step, gossip lowering (dense + ring), serve step."""
import os
import subprocess
import sys
import textwrap

import pytest

# tier-2: mesh dry-run subprocess battery (ROADMAP tier-1 runs
# -m "not slow")
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


PRELUDE = """
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import get_reduced, ShapeConfig
from repro.launch import steps as steps_lib, mesh as mesh_lib
from repro.core.topology import adjacency, row_stochastic
import repro.models.model as M
mesh = jax.make_mesh((4, 2), ("data", "model"))
assert len(jax.devices()) == 8
"""


def test_train_step_executes_on_mesh():
    out = _run(PRELUDE + """
cfg = get_reduced("qwen2-1.5b")
shape = ShapeConfig("t", 32, 8, "train")
step = steps_lib.make_train_step(cfg, mesh, lr=1e-2, mix_mode="dense")
param_sh, batch_sh, q_sh = steps_lib.make_shardings(mesh, cfg, shape)
key = jax.random.PRNGKey(0)
p0 = M.init_params(key, cfg)
params = jax.tree_util.tree_map(lambda p: jnp.broadcast_to(p[None], (4,) + p.shape), p0)
params = jax.device_put(params, param_sh)
batch = {"tokens": jax.device_put(
    jax.random.randint(key, (4, 2, 32), 0, cfg.vocab_size), batch_sh["tokens"])}
q = jax.device_put(row_stochastic(adjacency("cycle", 4)), q_sh)
jitted = jax.jit(step, in_shardings=(param_sh, batch_sh, q_sh),
                 out_shardings=(param_sh, None))
new_params, loss = jitted(params, batch, q)
assert np.isfinite(float(loss)), loss
changed = any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(
    jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(new_params)))
assert changed
print("TRAIN_STEP_OK", float(loss))
""")
    assert "TRAIN_STEP_OK" in out


def test_ring_mix_equals_dense_cycle():
    """collective_permute ring gossip == dense einsum with cycle Q."""
    out = _run(PRELUDE + """
from repro.core import mixing
n = 4
deltas = {"w": jax.random.normal(jax.random.PRNGKey(1), (n, 16))}
from jax.sharding import NamedSharding, PartitionSpec as P
sh = NamedSharding(mesh, P("data", None))
deltas = jax.device_put(deltas, {"w": sh})
q = row_stochastic(adjacency("cycle", n))  # 0.5 each neighbor
dense = mixing.mix_dense(q, deltas)
ring = jax.jit(lambda d: mixing.mix_ring_shardmap(mesh, ("data",), d))(deltas)
np.testing.assert_allclose(np.asarray(dense["w"]), np.asarray(ring["w"]),
                           atol=1e-5, rtol=1e-5)
print("RING_OK")
""")
    assert "RING_OK" in out


def test_serve_step_executes_on_mesh():
    out = _run(PRELUDE + """
cfg = get_reduced("mamba2-2.7b")
shape = ShapeConfig("d", 64, 8, "decode")
step = steps_lib.make_serve_step(cfg, shape, mesh)
param_sh, tok_sh, state_sh, cross_sh, scfg = steps_lib.serve_shardings(mesh, cfg, shape)
key = jax.random.PRNGKey(0)
params = jax.device_put(M.init_params(key, scfg), param_sh)
state = jax.device_put(M.init_decode_state(scfg, 8, 64), state_sh)
tok = jax.device_put(jnp.zeros((8,), jnp.int32), tok_sh)
jitted = jax.jit(step, in_shardings=(param_sh, tok_sh, state_sh),
                 out_shardings=(None, state_sh))
logits, state = jitted(params, tok, state)
assert np.isfinite(np.asarray(logits)).all()
logits2, state = jitted(params, tok, state)
assert int(state.pos) == 2
print("SERVE_OK")
""")
    assert "SERVE_OK" in out


def test_unify_step_on_mesh():
    out = _run(PRELUDE + """
cfg = get_reduced("stablelm-3b")
shape = ShapeConfig("t", 32, 8, "train")
param_sh, _, _ = steps_lib.make_shardings(mesh, cfg, shape)
key = jax.random.PRNGKey(0)
params = jax.vmap(lambda k: M.init_params(k, cfg))(jax.random.split(key, 4))
params = jax.device_put(params, param_sh)
unify = jax.jit(steps_lib.make_unify_step(cfg, mesh))
out_p = unify(params, jnp.asarray(2, jnp.int32))
for leaf in jax.tree_util.tree_leaves(out_p):
    assert float(jnp.abs(leaf - leaf[0:1]).max()) == 0.0
print("UNIFY_OK")
""")
    assert "UNIFY_OK" in out
