import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import (
    event_list,
    sample_event_masks,
    window_event_probs,
)


def test_window_probs():
    p = window_event_probs(0.1, 1.0)
    np.testing.assert_allclose(float(p), 1 - np.exp(-0.1), rtol=1e-6)
    assert float(window_event_probs(0.0, 1.0)) == 0.0
    assert float(window_event_probs(100.0, 1.0)) > 0.999


def test_event_mask_rate():
    key = jax.random.PRNGKey(0)
    lam, w, n, reps = 0.3, 1.0, 64, 200
    hits = 0
    for i in range(reps):
        m = sample_event_masks(jax.random.fold_in(key, i), lam, w, n)
        hits += int(m.sum())
    emp = hits / (n * reps)
    expected = 1 - np.exp(-lam * w)
    assert abs(emp - expected) < 0.01


def test_event_list_sorted_and_rates():
    rng = np.random.default_rng(0)
    evs = event_list(rng, n=10, horizon=500.0, lam_grad=0.1, lam_tx=0.2,
                     unify_period=50.0)
    ts = [e.t for e in evs]
    assert ts == sorted(ts)
    grads = [e for e in evs if e.kind == "grad"]
    txs = [e for e in evs if e.kind == "tx"]
    unifies = [e for e in evs if e.kind == "unify"]
    # Poisson counts: 10 clients * 500s * rate, within 4 sigma
    for got, lam in ((len(grads), 0.1), (len(txs), 0.2)):
        mean = 10 * 500 * lam
        assert abs(got - mean) < 4 * np.sqrt(mean)
    assert len(unifies) == 9  # 50,100,...,450


def test_event_list_per_client_independence():
    rng = np.random.default_rng(1)
    evs = event_list(rng, n=3, horizon=200.0, lam_grad=[0.5, 0.05, 0.0],
                     lam_tx=0.0)
    counts = {i: 0 for i in range(3)}
    for e in evs:
        counts[e.client] += 1
    assert counts[0] > counts[1] > counts[2] == 0
