import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import (
    event_list,
    sample_event_masks,
    unify_hub,
    window_event_probs,
)


def test_window_probs():
    p = window_event_probs(0.1, 1.0)
    np.testing.assert_allclose(float(p), 1 - np.exp(-0.1), rtol=1e-6)
    assert float(window_event_probs(0.0, 1.0)) == 0.0
    assert float(window_event_probs(100.0, 1.0)) > 0.999


def test_event_mask_rate():
    key = jax.random.PRNGKey(0)
    lam, w, n, reps = 0.3, 1.0, 64, 200
    hits = 0
    for i in range(reps):
        m = sample_event_masks(jax.random.fold_in(key, i), lam, w, n)
        hits += int(m.sum())
    emp = hits / (n * reps)
    expected = 1 - np.exp(-lam * w)
    assert abs(emp - expected) < 0.01


def test_event_list_sorted_and_rates():
    rng = np.random.default_rng(0)
    evs = event_list(rng, n=10, horizon=500.0, lam_grad=0.1, lam_tx=0.2,
                     unify_period=50.0)
    ts = [e.t for e in evs]
    assert ts == sorted(ts)
    grads = [e for e in evs if e.kind == "grad"]
    txs = [e for e in evs if e.kind == "tx"]
    unifies = [e for e in evs if e.kind == "unify"]
    # Poisson counts: 10 clients * 500s * rate, within 4 sigma
    for got, lam in ((len(grads), 0.1), (len(txs), 0.2)):
        mean = 10 * 500 * lam
        assert abs(got - mean) < 4 * np.sqrt(mean)
    assert len(unifies) == 9  # 50,100,...,450


def test_event_list_hub_matches_window_engine():
    """The exact timeline's unification hubs follow the SAME rotating
    rule as the compiled window engine (`protocol._unify` at the end of
    window `k*P - 1` picks `(widx // P) % n`): the two unification views
    agree event-for-event, incl. rotation wrap-around."""
    from repro.core.protocol import DracoConfig, _unify

    n, P = 4, 3
    rng = np.random.default_rng(0)
    evs = event_list(rng, n=n, horizon=10 * P + 0.5, lam_grad=0.1, lam_tx=0.1,
                     unify_period=float(P))
    hubs = [e.client for e in evs if e.kind == "unify"]
    assert len(hubs) == 10
    assert hubs == [unify_hub(k, n) for k in range(1, 11)]
    assert hubs[:5] == [0, 1, 2, 3, 0]  # deterministic rotation + wrap

    cfg = DracoConfig(num_clients=n, unify_period=P)
    for k in range(1, 11):
        widx = jnp.asarray(k * P - 1, jnp.int32)
        params = {"w": jnp.arange(n, dtype=jnp.float32)[:, None] + 100 * k}
        out, cnt = _unify(params, jnp.ones((n,), jnp.int32), widx, cfg, n)
        adopted = int(out["w"][0, 0]) - 100 * k  # all rows == the hub row
        assert (np.asarray(out["w"]) == np.asarray(out["w"][0])).all()
        assert adopted == hubs[k - 1], (k, adopted, hubs[k - 1])
        assert int(cnt.sum()) == 0  # unification resets the Psi counters


def test_event_list_random_hub_flag():
    """`random_hub=True` keeps the legacy uniform-random hub draw."""
    rng = np.random.default_rng(1)
    evs = event_list(rng, n=7, horizon=500.0, lam_grad=0.0, lam_tx=0.0,
                     unify_period=5.0, random_hub=True)
    hubs = [e.client for e in evs if e.kind == "unify"]
    assert len(hubs) == 99
    assert all(0 <= h < 7 for h in hubs)
    assert hubs != [unify_hub(k, 7) for k in range(1, 100)]


def test_event_list_per_client_independence():
    rng = np.random.default_rng(1)
    evs = event_list(rng, n=3, horizon=200.0, lam_grad=[0.5, 0.05, 0.0],
                     lam_tx=0.0)
    counts = {i: 0 for i in range(3)}
    for e in evs:
        counts[e.client] += 1
    assert counts[0] > counts[1] > counts[2] == 0


def test_sample_event_counts_high_rate_unbiased():
    """Regression: the old fixed ``max_count=8`` clipped any client with
    lam*w above ~4 (Pareto straggler profiles reach lam*w ~ 20), biasing
    its mean event count low. The default now sizes the truncation from
    the rate (mean + 6 sigma), pinning the clipped tail mass to ~0."""
    from repro.core.events import poisson_truncation_bound, sample_event_counts

    lam, w, n, reps = 20.0, 1.0, 256, 40
    key = jax.random.PRNGKey(0)
    tot_new = tot_old = 0.0
    peak = 0
    for i in range(reps):
        k = jax.random.fold_in(key, i)
        c_new = sample_event_counts(k, lam, w, n)
        c_old = sample_event_counts(k, lam, w, n, max_count=8)
        tot_new += float(c_new.sum())
        tot_old += float(c_old.sum())
        peak = max(peak, int(c_new.max()))
    mean_new = tot_new / (n * reps)
    mean_old = tot_old / (n * reps)
    # unbiased within 4 sigma of the sample mean...
    assert abs(mean_new - lam * w) < 4 * np.sqrt(lam * w / (n * reps))
    # ...while the legacy cap pinned everything at 8
    assert mean_old <= 8.0
    assert abs(mean_old - 8.0) < 0.05
    # the sized bound actually covers the samples (tail mass ~1e-9)
    bound = poisson_truncation_bound(lam * w)
    assert peak <= bound
    assert bound < lam * w + 7 * np.sqrt(lam * w)


def test_truncation_bound_monotone_and_floored():
    from repro.core.events import poisson_truncation_bound

    bounds = [poisson_truncation_bound(x) for x in (0.0, 0.5, 2.0, 50.0)]
    assert bounds == sorted(bounds)
    assert bounds[0] >= 6  # near-zero rates still admit stray events


def test_event_list_hub_three_views_agree():
    """event_list, the packed EventTape, and the window engine's `_unify`
    name the same rotating hub for every unification."""
    from repro.events import KIND_UNIFY, tape_from_events

    n, P = 4, 3
    rng = np.random.default_rng(2)
    evs = event_list(rng, n=n, horizon=10 * P + 0.5, lam_grad=0.2,
                     lam_tx=0.2, unify_period=float(P))
    tape = tape_from_events(evs, capacity=len(evs) + 5)
    kinds = np.asarray(tape.kind)[np.asarray(tape.valid)]
    clients = np.asarray(tape.client)[np.asarray(tape.valid)]
    tape_hubs = clients[kinds == KIND_UNIFY].tolist()
    assert tape_hubs == [e.client for e in evs if e.kind == "unify"]
    assert tape_hubs == [unify_hub(k, n) for k in range(1, 11)]
