"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.mixing import mix_dense, psi_cap_mask
from repro.core.topology import adjacency, is_row_stochastic, metropolis, row_stochastic

TOPOS = st.sampled_from(["cycle", "complete", "star", "erdos"])


@settings(max_examples=30, deadline=None)
@given(topo=TOPOS, n=st.integers(3, 40), seed=st.integers(0, 1000))
def test_row_stochastic_always(topo, n, seed):
    adj = adjacency(topo, n, key=jax.random.PRNGKey(seed))
    q = row_stochastic(adj)
    assert is_row_stochastic(q)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 30), psi=st.integers(1, 6), seed=st.integers(0, 1000))
def test_psi_cap_budget_always(n, psi, seed):
    q = row_stochastic(adjacency("complete", n))
    capped = psi_cap_mask(jax.random.PRNGKey(seed), q, psi)
    incoming = np.asarray((capped > 0).sum(0))
    assert (incoming <= psi).all()
    # capping never increases any weight
    assert (np.asarray(capped) <= np.asarray(q) + 1e-9).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 16), d=st.integers(1, 64), seed=st.integers(0, 1000))
def test_mixing_mass_conservation(n, d, seed):
    """Row-stochastic mixing redistributes but never creates mass:
    sum_j out_j == sum_i (rowsum_i) delta_i == sum_i delta_i."""
    key = jax.random.PRNGKey(seed)
    q = row_stochastic(adjacency("complete", n))
    deltas = {"w": jax.random.normal(jax.random.fold_in(key, 1), (n, d))}
    out = mix_dense(q, deltas)
    np.testing.assert_allclose(np.asarray(out["w"].sum(0)),
                               np.asarray(deltas["w"].sum(0)), atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(3, 20), seed=st.integers(0, 1000))
def test_metropolis_spectral(n, seed):
    """Metropolis matrix: doubly stochastic, symmetric, eigenvalues in
    [-1, 1] with lambda_1 = 1 (consensus-preserving)."""
    adj = adjacency("erdos", n, key=jax.random.PRNGKey(seed))
    w = np.asarray(metropolis(adj))
    ev = np.linalg.eigvalsh(w)
    assert ev.max() <= 1.0 + 1e-5
    assert ev.min() >= -1.0 - 1e-5
    np.testing.assert_allclose(ev.max(), 1.0, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 12), d=st.integers(1, 32), seed=st.integers(0, 500))
def test_mix_permutation_equivariance(n, d, seed):
    """Relabeling clients commutes with mixing: P^T Q^T D = (QP)^T ..."""
    key = jax.random.PRNGKey(seed)
    q = row_stochastic(adjacency("complete", n))
    deltas = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    perm = jax.random.permutation(jax.random.fold_in(key, 2), n)
    out = mix_dense(q, {"w": deltas})["w"]
    q_p = q[perm][:, perm]
    out_p = mix_dense(q_p, {"w": deltas[perm]})["w"]
    np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), b=st.integers(1, 3), s=st.sampled_from([8, 16]))
def test_model_logits_finite_random_inputs(seed, b, s):
    """Unified decoder never produces NaN on random tokens (reduced dense)."""
    from repro.configs.base import get_reduced
    from repro.models.registry import build_model

    cfg = get_reduced("qwen2-1.5b")
    m = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = m.init(key)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0, cfg.vocab_size)
    logits, _ = m.apply(params, {"tokens": toks})
    assert bool(jnp.isfinite(logits).all())
