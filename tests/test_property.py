"""Property tests on system invariants, for every topology generator.

Two tiers:

1. A **deterministic battery** that always runs (no hypothesis needed):
   row-stochasticity (`is_row_stochastic`), zero diagonal, Metropolis
   symmetry/double-stochasticity, and Q-on-adjacency support — checked
   for every static topology AND for every registered time-varying
   scenario generator at 50 random schedule steps (the exact in-scan
   view, `schedule.at(t)`).
2. A **hypothesis fuzz battery** over the same invariants plus mixing
   algebra (mass conservation, permutation equivariance, Psi budget,
   spectral bounds), active whenever `hypothesis` is importable —
   `requirements-dev.txt` pins it, so CI always fuzzes; only bare
   runtime-only environments fall back to tier 1 alone.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.protocol import DracoConfig
from repro.core.topology import (
    adjacency,
    is_row_stochastic,
    metropolis,
    row_stochastic,
)
from repro.scenarios import check_snapshot, list_scenarios, make_schedule

# tier-2: hypothesis fuzz + invariant battery (ROADMAP tier-1 runs -m "not slow")
pytestmark = pytest.mark.slow

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

STATIC_TOPOS = ["cycle", "complete", "star", "erdos"]
NUM_SCHEDULE_STEPS = 50


# --------------------------------------------------------------------------
# Tier 1: deterministic battery (always runs)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("topo", STATIC_TOPOS + ["ring2d"])
@pytest.mark.parametrize("directed", [False, True])
def test_static_topology_invariants(topo, directed):
    for n, seed in ((9, 0), (16, 1)):
        adj = adjacency(topo, n, key=jax.random.PRNGKey(seed),
                        directed=directed)
        check_snapshot(row_stochastic(adj), adj, metropolis(adj),
                       label=f"({topo}, n={n}, directed={directed})")


@pytest.mark.parametrize("gen", list_scenarios())
@pytest.mark.parametrize("seed", [0, 1])
def test_scenario_invariants_at_50_random_steps(gen, seed):
    """Every registered scenario generator — including every time-varying
    one — upholds the invariants at 50 random schedule steps, sampled
    past the ring period so wrap-around rows are covered too."""
    cfg = DracoConfig(num_clients=7, topology="erdos")
    kw = {} if gen == "static" else {"steps": 12}
    sched = make_schedule(gen, cfg, key=jax.random.PRNGKey(seed), **kw)
    rng = np.random.default_rng(seed)
    for t in rng.integers(0, 4 * sched.period, size=NUM_SCHEDULE_STEPS):
        snap = sched.at(int(t))
        check_snapshot(snap.q, snap.adj, snap.w_sym,
                       label=f"({gen}, step {t})")
        for rate in (snap.compute_rate, snap.tx_rate):
            if rate is not None:
                assert bool(jnp.all(rate >= 0)), f"negative rate ({gen}, {t})"


# --------------------------------------------------------------------------
# Tier 2: hypothesis fuzz battery (runs whenever hypothesis is installed)
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    TOPOS = st.sampled_from(STATIC_TOPOS)
    GENS = st.sampled_from(list_scenarios())

    @settings(max_examples=30, deadline=None)
    @given(topo=TOPOS, n=st.integers(3, 40), seed=st.integers(0, 1000))
    def test_row_stochastic_always(topo, n, seed):
        adj = adjacency(topo, n, key=jax.random.PRNGKey(seed))
        q = row_stochastic(adj)
        assert is_row_stochastic(q)

    @settings(max_examples=10, deadline=None)
    @given(gen=GENS, topo=TOPOS, n=st.integers(4, 12),
           seed=st.integers(0, 1000), steps=st.integers(1, 6))
    def test_scenario_invariants_fuzzed(gen, topo, n, seed, steps):
        """Random (generator, base topology, size, seed, ring length):
        every scheduled step upholds the invariant triple."""
        cfg = DracoConfig(num_clients=n, topology=topo)
        kw = {} if gen == "static" else {"steps": steps}
        sched = make_schedule(gen, cfg, key=jax.random.PRNGKey(seed), **kw)
        for t in range(sched.period):
            snap = sched.at(t)
            check_snapshot(snap.q, snap.adj, snap.w_sym,
                           label=f"({gen}/{topo}, step {t})")

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(3, 30), psi=st.integers(1, 6),
           seed=st.integers(0, 1000))
    def test_psi_cap_budget_always(n, psi, seed):
        from repro.core.mixing import psi_cap_mask

        q = row_stochastic(adjacency("complete", n))
        capped = psi_cap_mask(jax.random.PRNGKey(seed), q, psi)
        incoming = np.asarray((capped > 0).sum(0))
        assert (incoming <= psi).all()
        # capping never increases any weight
        assert (np.asarray(capped) <= np.asarray(q) + 1e-9).all()

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(2, 16), d=st.integers(1, 64),
           seed=st.integers(0, 1000))
    def test_mixing_mass_conservation(n, d, seed):
        """Row-stochastic mixing redistributes but never creates mass:
        sum_j out_j == sum_i (rowsum_i) delta_i == sum_i delta_i."""
        from repro.core.mixing import mix_dense

        key = jax.random.PRNGKey(seed)
        q = row_stochastic(adjacency("complete", n))
        deltas = {"w": jax.random.normal(jax.random.fold_in(key, 1), (n, d))}
        out = mix_dense(q, deltas)
        np.testing.assert_allclose(np.asarray(out["w"].sum(0)),
                                   np.asarray(deltas["w"].sum(0)), atol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(3, 20), seed=st.integers(0, 1000))
    def test_metropolis_spectral(n, seed):
        """Metropolis matrix: doubly stochastic, symmetric, eigenvalues in
        [-1, 1] with lambda_1 = 1 (consensus-preserving)."""
        adj = adjacency("erdos", n, key=jax.random.PRNGKey(seed))
        w = np.asarray(metropolis(adj))
        ev = np.linalg.eigvalsh(w)
        assert ev.max() <= 1.0 + 1e-5
        assert ev.min() >= -1.0 - 1e-5
        np.testing.assert_allclose(ev.max(), 1.0, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(2, 12), d=st.integers(1, 32), seed=st.integers(0, 500))
    def test_mix_permutation_equivariance(n, d, seed):
        """Relabeling clients commutes with mixing: P^T Q^T D = (QP)^T ..."""
        from repro.core.mixing import mix_dense

        key = jax.random.PRNGKey(seed)
        q = row_stochastic(adjacency("complete", n))
        deltas = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
        perm = jax.random.permutation(jax.random.fold_in(key, 2), n)
        out = mix_dense(q, {"w": deltas})["w"]
        q_p = q[perm][:, perm]
        out_p = mix_dense(q_p, {"w": deltas[perm]})["w"]
        np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p),
                                   atol=1e-4, rtol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100), b=st.integers(1, 3),
           s=st.sampled_from([8, 16]))
    def test_model_logits_finite_random_inputs(seed, b, s):
        """Unified decoder never produces NaN on random tokens."""
        from repro.configs.base import get_reduced
        from repro.models.registry import build_model

        cfg = get_reduced("qwen2-1.5b")
        m = build_model(cfg)
        key = jax.random.PRNGKey(seed)
        params = m.init(key)
        toks = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 0,
                                  cfg.vocab_size)
        logits, _ = m.apply(params, {"tokens": toks})
        assert bool(jnp.isfinite(logits).all())
