"""Client-axis sharding of the sweep engine, on an 8-device CPU mesh.

Subprocess tests (XLA device count must be set before jax initializes,
per project policy — see tests/test_dryrun_small.py):

  - `gossip_drain_sharded`: the explicit shard_map lowering (per-device
    drain tiles + one `psum_scatter` on the receiver axis) equals the
    single-device `gossip_drain`.
  - `simulate_sweep(..., mesh=...)`: the auto-SPMD client-sharded grid
    matches the unsharded grid (up to f32 reduction order) and actually
    lays the client axis out over the mesh.
"""
import os
import subprocess
import sys
import textwrap

import pytest

# tier-2: 8-device CPU mesh subprocess battery (ROADMAP tier-1 runs
# -m "not slow")
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


PRELUDE = """
import jax, jax.numpy as jnp
import numpy as np
from repro.launch.mesh import make_sweep_mesh
assert len(jax.devices()) == 8
mesh = make_sweep_mesh()
"""


def test_gossip_drain_sharded_matches_reference():
    out = _run(PRELUDE + """
from repro.kernels.gossip.ops import gossip_drain, gossip_drain_sharded
key = jax.random.PRNGKey(0)
J, S, N, K = 3, 4, 16, 37
w = jax.random.normal(key, (J, N, N)) * (
    jax.random.uniform(jax.random.fold_in(key, 1), (J, N, N)) < 0.3)
ring = jax.random.normal(jax.random.fold_in(key, 2), (S, N, K))
slots = jnp.array([1, 3, 0])
ref = gossip_drain(w, ring, slots)
out = jax.jit(lambda w, r, s: gossip_drain_sharded(w, r, s, mesh, ("data",)))(
    w, ring, slots)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=1e-5, rtol=1e-5)
assert "data" in str(out.sharding.spec), out.sharding
# empty-weight buckets contribute exact zero on every shard
w0 = w.at[1].set(0.0)
ref0 = gossip_drain(w0, ring, slots)
out0 = jax.jit(lambda w, r, s: gossip_drain_sharded(w, r, s, mesh, ("data",)))(
    w0, ring, slots)
np.testing.assert_allclose(np.asarray(out0), np.asarray(ref0),
                           atol=1e-5, rtol=1e-5)
# the TPU path hands each device a RECTANGULAR (J, N/8, N) weight slice;
# exercise the Pallas kernel (interpret mode) through the same shard_map
out_k = jax.jit(lambda w, r, s: gossip_drain_sharded(
    w, r, s, mesh, ("data",), use_kernel=True, interpret=True))(w, ring, slots)
np.testing.assert_allclose(np.asarray(out_k), np.asarray(ref),
                           atol=1e-5, rtol=1e-5)
print("DRAIN_SHARDED_OK")
""")
    assert "DRAIN_SHARDED_OK" in out


def test_drain_sharded_rejects_indivisible():
    out = _run(PRELUDE + """
from repro.kernels.gossip.ops import gossip_drain_sharded
try:
    gossip_drain_sharded(jnp.zeros((2, 9, 9)), jnp.zeros((3, 9, 4)),
                         jnp.array([0, 1]), mesh, ("data",))
except ValueError as e:
    assert "divisible" in str(e)
    print("INDIVISIBLE_OK")
""")
    assert "INDIVISIBLE_OK" in out


def test_sweep_on_mesh_matches_unsharded():
    out = _run(PRELUDE + """
from repro.api import simulate_sweep
from repro.core.protocol import DracoConfig
from repro.data.synthetic import federated_classification, make_mlp
N = 8
key = jax.random.PRNGKey(0)
k1, k2 = jax.random.split(key)
train, test = federated_classification(k1, N, input_dim=6, num_classes=3,
                                       per_client=32)
params0, apply, loss, acc = make_mlp(k2, 6, (8,), 3)
cfg = DracoConfig(num_clients=N, lr=0.1, local_batches=1, batch_size=8,
                  lambda_grad=0.8, lambda_tx=0.8, unify_period=5, psi=2,
                  topology="complete", max_delay_windows=3, channel=None)
keys = jax.random.split(jax.random.PRNGKey(7), 2)
grid = [cfg.replace(psi=p) for p in (0, 2)]
kw = dict(keys=keys, eval_every=4, eval_fn=acc, eval_data=test)
f_plain, t_plain = simulate_sweep("draco", grid, params0, loss, train, 8, **kw)
f_mesh, t_mesh = simulate_sweep("draco", grid, params0, loss, train, 8,
                                mesh=mesh, **kw)
for a, b in zip(jax.tree_util.tree_leaves(f_plain.params),
                jax.tree_util.tree_leaves(f_mesh.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)
np.testing.assert_allclose(t_plain.metrics["accuracy"],
                           t_mesh.metrics["accuracy"], atol=1e-5)
shardings = {str(l.sharding.spec)
             for l in jax.tree_util.tree_leaves(f_mesh.params)}
assert any("data" in s for s in shardings), shardings
print("MESH_SWEEP_OK")
""")
    assert "MESH_SWEEP_OK" in out
