"""The continuous-time event engine: tape, scan, replay parity, sweeps.

The load-bearing assertion is **replay parity**: the jitted tape scan
(`repro.events.engine`) equals the step-by-step eager oracle
(`repro.events.replay`) bit-for-bit — same RNG contract, same drain
order, same f32 accumulation — for every member of the algorithm
family. Everything else (suppression, staleness, sweep integration,
scenario-profiled tapes, padding) builds on that.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import simulate, simulate_sweep
from repro.core.channel import ChannelConfig
from repro.events import (
    EventConfig,
    EventTape,
    KIND_GRAD,
    KIND_TX,
    KIND_UNIFY,
    events_context,
    init_event_state,
    replay_events,
    sample_event_tape,
    simulate_events,
    staleness_damping_vector,
    staleness_scale,
    tape_capacity,
    tape_from_events,
)
from repro.events.staleness import staleness_fn
from repro.tasks import get_task

N = 5
HORIZON = 20.0

_TASK = get_task("linear-softmax")
_KP, _KD = jax.random.split(jax.random.PRNGKey(0))
_PARAMS0 = _TASK.init_params(_KP)


def _cfg(**kw):
    base = dict(num_clients=N, lr=0.05, local_batches=1, batch_size=8,
                lambda_grad=0.4, lambda_tx=0.4, unify_period=8, psi=2,
                topology="cycle", max_delay_windows=3, channel=None)
    base.update(kw)
    return EventConfig(**base)


def _ctx(cfg, horizon=HORIZON, tape_seed=3, **kw):
    data, _ = _TASK.make_data(_KD, cfg.num_clients)
    return events_context(cfg, _TASK, data, params0=_PARAMS0,
                          horizon=horizon, tape_seed=tape_seed, **kw)


def _assert_state_equals_replay(st, rp):
    for field in ("pending", "opt_state", "accept_count", "total_accept",
                  "tx_sent"):
        a = np.asarray(getattr(st, field))
        b = np.asarray(getattr(rp, field))
        assert (a == b).all(), (field, a, b)
    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(rp.params)):
        assert (np.asarray(a) == np.asarray(b)).all(), "params diverged"
    assert int(st.tx_count) == rp.tx_count


def _parity(cfg, algo, horizon=HORIZON):
    ctx = _ctx(cfg, horizon=horizon)
    key = jax.random.PRNGKey(7)
    st, _ = simulate_events(algo, cfg, ctx=ctx, key=key)
    st0 = init_event_state(key, cfg, _PARAMS0, task=_TASK)
    damping = staleness_fn(cfg) if algo == "fedasync-gossip" else None
    trigger = (float(cfg.trigger_threshold)
               if algo == "event-triggered" else 0.0)
    rp = replay_events(st0, ctx, damping=damping, trigger=trigger)
    _assert_state_equals_replay(st, rp)
    return st, rp, ctx


# ---------------------------------------------------------------------------
# tape construction
# ---------------------------------------------------------------------------


def test_tape_sorted_padded_and_counted():
    cfg = _cfg()
    tape = sample_event_tape(cfg, HORIZON, seed=0)
    v = np.asarray(tape.valid)
    t = np.asarray(tape.t)[v]
    assert (np.diff(t) >= 0).all()
    assert tape.capacity == tape_capacity(cfg, HORIZON)
    assert tape.num_valid <= tape.capacity
    c = tape.counts()
    # 2 unifications at 8s and 16s; Poisson counts within 6 sigma
    assert c["unify"] == 2
    mean = N * HORIZON * 0.4
    for kind in ("grad", "tx"):
        assert abs(c[kind] - mean) < 6 * np.sqrt(mean) + 1


def test_tape_overflow_raises():
    cfg = _cfg()
    from repro.core.events import event_list

    evs = event_list(np.random.default_rng(0), N, HORIZON, 0.4, 0.4)
    with pytest.raises(ValueError, match="exceed tape capacity"):
        tape_from_events(evs, capacity=3)


def test_tape_capacity_covers_peak_profile_rates():
    """The E rule sizes from ring-modulated *peak* rates: straggler
    slowdowns shrink the tape, a rate boost grows it."""
    from repro.scenarios import make_schedule
    from repro.scenarios.base import Schedule

    cfg = _cfg(unify_period=0)
    plain = tape_capacity(cfg, 100.0)
    slow = make_schedule("straggler-profile", cfg,
                         key=jax.random.PRNGKey(1),
                         straggler_frac=1.0, slowdown=4.0)
    assert slow.compute_rate is not None
    assert tape_capacity(cfg, 100.0, schedule=slow) < plain
    boost = Schedule(q=slow.q, adj=slow.adj, w_sym=slow.w_sym,
                     compute_rate=jnp.full((1, N), 3.0, jnp.float32))
    assert tape_capacity(cfg, 100.0, schedule=boost) > plain


# ---------------------------------------------------------------------------
# replay parity (bit-for-bit)
# ---------------------------------------------------------------------------


def test_draco_event_matches_replay_bitwise():
    _parity(_cfg(), "draco-event")


def test_draco_event_matches_replay_with_channel():
    _parity(_cfg(channel=ChannelConfig(gamma_max=3.0)), "draco-event")


def test_fedasync_gossip_matches_replay_bitwise():
    _parity(_cfg(staleness="poly", staleness_a=0.7), "fedasync-gossip")


def test_event_triggered_matches_replay_bitwise():
    st, rp, ctx = _parity(_cfg(trigger_threshold=0.05), "event-triggered")
    # suppression must be observable: fewer broadcasts than tx events
    assert int(np.asarray(st.tx_sent).sum()) < ctx.tape.counts()["tx"]


def test_padded_tape_is_noop_suffix():
    """Extra padding rows leave the final state bit-for-bit unchanged."""
    cfg = _cfg()
    ctx = _ctx(cfg)
    key = jax.random.PRNGKey(9)
    st_a, _ = simulate_events("draco-event", cfg, ctx=ctx, key=key)
    wide = EventTape(
        jnp.concatenate([ctx.tape.t, ctx.tape.t[-8:]]),
        jnp.concatenate([ctx.tape.client, ctx.tape.client[-8:]]),
        jnp.concatenate([ctx.tape.kind, ctx.tape.kind[-8:]]),
        jnp.concatenate([ctx.tape.valid,
                         jnp.zeros((8,), bool)]))
    st_b, _ = simulate_events("draco-event", cfg, ctx=ctx, tape=wide, key=key)
    assert int(st_b.event_idx) == int(st_a.event_idx) + 8
    for a, b in zip(jax.tree_util.tree_leaves(st_a.params),
                    jax.tree_util.tree_leaves(st_b.params)):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert (np.asarray(st_a.key) == np.asarray(st_b.key)).all()


# ---------------------------------------------------------------------------
# event semantics
# ---------------------------------------------------------------------------


def _manual_tape(rows, capacity=None):
    t, client, kind = zip(*rows)
    cap = capacity or len(rows)
    pad = cap - len(rows)
    return EventTape(
        jnp.asarray(np.concatenate([t, [t[-1]] * pad]).astype(np.float32)),
        jnp.asarray(np.concatenate([client, [0] * pad]).astype(np.int32)),
        jnp.asarray(np.concatenate([kind, [0] * pad]).astype(np.int32)),
        jnp.asarray([True] * len(rows) + [False] * pad))


def test_delivery_waits_for_next_event():
    """Channel off: a broadcast lands at the next strictly-later event
    (the window->0 limit), not instantaneously."""
    cfg = _cfg(unify_period=0, psi=0, topology="complete")
    tape = _manual_tape([(1.0, 0, KIND_GRAD), (2.0, 0, KIND_TX),
                         (3.0, 1, KIND_GRAD)])
    ctx = _ctx(cfg, tape_seed=0).replace(tape=tape)
    key = jax.random.PRNGKey(1)
    st0 = init_event_state(key, cfg, _PARAMS0, task=_TASK)
    p0 = jax.tree_util.tree_leaves(st0.params)[0]

    # after the tx event nothing has been delivered yet...
    two = ctx.replace(tape=_manual_tape([(1.0, 0, KIND_GRAD),
                                         (2.0, 0, KIND_TX)]))
    st2, _ = simulate_events("draco-event", cfg, ctx=two, key=key)
    receivers_2 = jax.tree_util.tree_leaves(st2.params)[0][1:]
    assert (np.asarray(receivers_2) == np.asarray(p0[1:])).all()
    # ...but the next event (any client's) triggers the drain
    st3, _ = simulate_events("draco-event", cfg, ctx=ctx, key=key)
    receivers_3 = jax.tree_util.tree_leaves(st3.params)[0][1:]
    assert not (np.asarray(receivers_3) == np.asarray(p0[1:])).all()
    # sender never applies its own update (paper semantics)
    assert (np.asarray(jax.tree_util.tree_leaves(st2.params)[0][0])
            == np.asarray(p0[0])).all()


def test_unify_event_adopts_hub_and_resets_psi():
    cfg = _cfg(unify_period=8, psi=1, topology="complete")
    hub = 3
    tape = _manual_tape([(1.0, 0, KIND_GRAD), (2.0, 0, KIND_TX),
                         (3.0, 1, KIND_GRAD), (8.0, hub, KIND_UNIFY)])
    ctx = _ctx(cfg).replace(tape=tape)
    key = jax.random.PRNGKey(2)
    st, _ = simulate_events("draco-event", cfg, ctx=ctx, key=key)
    for leaf in jax.tree_util.tree_leaves(st.params):
        x = np.asarray(leaf)
        assert (x == x[hub]).all()
    assert (np.asarray(st.accept_count) == 0).all()
    assert int(np.asarray(st.total_accept).sum()) > 0


def test_trigger_zero_is_draco_event_bitwise():
    cfg = _cfg(trigger_threshold=0.0)
    ctx = _ctx(cfg)
    key = jax.random.PRNGKey(4)
    st_a, _ = simulate_events("draco-event", cfg, ctx=ctx, key=key)
    st_b, _ = simulate_events("event-triggered", cfg, ctx=ctx, key=key)
    for a, b in zip(jax.tree_util.tree_leaves(st_a.params),
                    jax.tree_util.tree_leaves(st_b.params)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_constant_staleness_is_draco_event_bitwise():
    cfg = _cfg(staleness="constant")
    ctx = _ctx(cfg)
    key = jax.random.PRNGKey(4)
    st_a, _ = simulate_events("draco-event", cfg, ctx=ctx, key=key)
    st_b, _ = simulate_events("fedasync-gossip", cfg, ctx=ctx, key=key)
    for a, b in zip(jax.tree_util.tree_leaves(st_a.params),
                    jax.tree_util.tree_leaves(st_b.params)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_staleness_families():
    s = staleness_scale
    np.testing.assert_allclose(np.asarray(s("constant", [0.0, 9.0])), 1.0)
    hinge = np.asarray(s("hinge", [1.0, 4.0, 8.0], a=0.5, b=4.0))
    np.testing.assert_allclose(hinge[:2], 1.0)
    np.testing.assert_allclose(hinge[2], 1.0 / 3.0, rtol=1e-6)
    # continuous at the grace period and bounded by 1 (no pole at b)
    near_b = np.asarray(s("hinge", [4.0 + 1e-6, 4.5, 100.0], a=0.5, b=4.0))
    np.testing.assert_allclose(near_b[0], 1.0, rtol=1e-5)
    assert (near_b <= 1.0).all() and (near_b > 0.0).all()
    assert near_b[0] > near_b[1] > near_b[2]
    poly = np.asarray(s("poly", [0.0, 3.0], a=0.5))
    np.testing.assert_allclose(poly, [1.0, 0.5], rtol=1e-6)
    with pytest.raises(ValueError):
        s("exp", 1.0)
    vec = staleness_damping_vector(_cfg(staleness="poly", staleness_a=0.5,
                                        max_delay_windows=4))
    assert vec.shape == (4,)
    assert staleness_damping_vector(_cfg()) is None


def test_event_config_validation():
    with pytest.raises(ValueError, match="staleness"):
        _cfg(staleness="exp")
    with pytest.raises(ValueError, match="trigger_threshold"):
        _cfg(trigger_threshold=-1.0)
    with pytest.raises(ValueError, match="staleness_b"):
        _cfg(staleness_b=-1.0)


# ---------------------------------------------------------------------------
# scenario-profiled tapes
# ---------------------------------------------------------------------------


def test_profiled_tape_respects_duty_cycle():
    """Clients with zero compute rate in off-windows fire no grad events
    there; a fully-off client fires none at all."""
    cfg = _cfg(unify_period=0, lambda_tx=0.0)
    from repro.scenarios.base import Schedule

    base = _ctx(cfg, tape_seed=0)
    rate = np.ones((4, N), np.float32)
    rate[:, 0] = 0.0           # client 0 never computes
    rate[:2, 1] = 0.0          # client 1 off in windows 0,1 mod 4
    sched = Schedule(q=base.schedule.q if base.schedule else base.q[None],
                     adj=base.adj[None], w_sym=base.w_sym[None],
                     compute_rate=jnp.asarray(rate))
    tape = sample_event_tape(cfg, 200.0, seed=5, schedule=sched)
    v = np.asarray(tape.valid)
    cl = np.asarray(tape.client)[v]
    tt = np.asarray(tape.t)[v]
    assert (cl != 0).all()
    w1 = np.floor(tt[cl == 1] / cfg.window).astype(int) % 4
    assert (w1 >= 2).all()
    assert (cl == 1).sum() > 0  # thinning kept the on-windows


# ---------------------------------------------------------------------------
# sweep integration
# ---------------------------------------------------------------------------


def test_event_family_sweeps_in_one_call():
    """All three event algorithms run lr x psi grids through
    `simulate_sweep` over a tape-carrying ctx; row (g, k) equals the solo
    run bit-for-bit."""
    cfg = _cfg()
    ctx = _ctx(cfg)
    keys = jax.random.split(jax.random.PRNGKey(11), 2)
    grid = [cfg, cfg.replace(lr=0.1), cfg.replace(psi=4)]
    for algo in ("draco-event", "fedasync-gossip", "event-triggered"):
        finals, _ = simulate_sweep(algo, grid, ctx=ctx, keys=keys,
                                   task=_TASK, num_steps=ctx.tape.capacity)
        solo, _ = simulate_events(algo, grid[1], ctx=ctx.replace(cfg=grid[1]),
                                  key=keys[1])
        for a, b in zip(jax.tree_util.tree_leaves(finals.params),
                        jax.tree_util.tree_leaves(solo.params)):
            assert (np.asarray(a)[1, 1] == np.asarray(b)).all(), algo


def test_lambda_sweep_is_rejected_for_event_algos():
    """The Poisson rates are baked into the sampled tape — sweeping them
    inside one compiled call would silently reuse the wrong tape."""
    cfg = _cfg()
    ctx = _ctx(cfg)
    with pytest.raises(ValueError, match="does not consume"):
        simulate_sweep("draco-event", [cfg, cfg.replace(lambda_tx=0.8)],
                       ctx=ctx, task=_TASK, key=jax.random.PRNGKey(0),
                       num_seeds=1, num_steps=ctx.tape.capacity)


def test_fedasync_window_constant_is_draco_bitwise():
    """The windowed damping hook with a constant family is a no-op."""
    cfg = _cfg(staleness="constant")
    data, _ = _TASK.make_data(_KD, cfg.num_clients)
    key = jax.random.PRNGKey(3)
    st_a, _ = simulate("draco", cfg, task=_TASK, data=data,
                       params0=_PARAMS0, num_steps=40, key=key)
    st_b, _ = simulate("fedasync-window", cfg, task=_TASK, data=data,
                       params0=_PARAMS0, num_steps=40, key=key)
    for a, b in zip(jax.tree_util.tree_leaves(st_a.params),
                    jax.tree_util.tree_leaves(st_b.params)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_fedasync_window_damps_arrivals():
    """A poly family shrinks what arrives vs. undamped DRACO."""
    cfg = _cfg(staleness="poly", staleness_a=2.0, unify_period=0,
               topology="complete")
    data, _ = _TASK.make_data(_KD, cfg.num_clients)
    key = jax.random.PRNGKey(3)
    st_a, _ = simulate("draco", cfg, task=_TASK, data=data,
                       params0=_PARAMS0, num_steps=40, key=key)
    st_b, _ = simulate("fedasync-window", cfg, task=_TASK, data=data,
                       params0=_PARAMS0, num_steps=40, key=key)
    # same events, same sends — only the mixing weights differ
    moved_a = sum(float(np.abs(np.asarray(l) - np.asarray(l0)).sum())
                  for l, l0 in zip(jax.tree_util.tree_leaves(st_a.params),
                                   jax.tree_util.tree_leaves(
                                       _TASK.init_params(_KP))))
    moved_b = sum(float(np.abs(np.asarray(l) - np.asarray(l0)).sum())
                  for l, l0 in zip(jax.tree_util.tree_leaves(st_b.params),
                                   jax.tree_util.tree_leaves(
                                       _TASK.init_params(_KP))))
    assert moved_a != moved_b


def test_grads_per_step_and_budget():
    from repro.api import steps_for_budget

    cfg = _cfg(lambda_grad=0.3, lambda_tx=0.1)
    from repro.api import get_algorithm

    r = get_algorithm("draco-event").grads_per_step(cfg)
    np.testing.assert_allclose(r, 0.3 / (N * 0.4), rtol=1e-6)
    assert steps_for_budget("draco-event", cfg, 10.0) == round(10.0 / r)
