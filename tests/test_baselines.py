import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import (
    BASELINES,
    eval_params,
    init_baseline_state,
    run_baseline,
    sync_push_round,
)
from repro.core.protocol import DracoConfig
from repro.data.synthetic import federated_classification, make_mlp

N = 6


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    train, test = federated_classification(k1, N, input_dim=8, num_classes=4,
                                           per_client=128)
    params0, apply, loss, acc = make_mlp(k2, 8, (16,), 4)
    return train, test, params0, loss, acc


@pytest.mark.parametrize("method", BASELINES)
def test_baseline_learns(method, task):
    train, test, params0, loss, acc = task
    cfg = DracoConfig(num_clients=N, lr=0.1, local_batches=1, batch_size=16,
                      topology="complete", channel=None)
    st = init_baseline_state(jax.random.PRNGKey(1), cfg, params0)
    tx_, ty_ = test
    acc0 = float(jax.vmap(lambda p: acc(p, tx_, ty_))(st.params).mean())
    st = run_baseline(method, st, cfg, loss, train, 80)
    p = eval_params(method, st)
    acc1 = float(jax.vmap(lambda pp: acc(pp, tx_, ty_))(p).mean())
    assert acc1 > acc0 + 0.15, (method, acc0, acc1)


def test_push_sum_mass_conservation(task):
    """Push-sum invariant: sum_i w_i == N and the weighted average of
    (params * w) is preserved by the mixing (no local update)."""
    train, _, params0, loss, _ = task
    cfg = DracoConfig(num_clients=N, lr=0.0, local_batches=1, batch_size=16,
                      topology="cycle", channel=None)
    st = init_baseline_state(jax.random.PRNGKey(2), cfg, params0)
    total0 = [np.asarray(l.sum(0)) for l in jax.tree_util.tree_leaves(st.params)]
    st2, _ = sync_push_round(st, cfg,
                             adj=jnp.asarray(~np.eye(N, dtype=bool)),
                             task=loss, data=train)
    np.testing.assert_allclose(float(st2.push_weight.sum()), N, rtol=1e-5)
    total1 = [np.asarray(l.sum(0)) for l in jax.tree_util.tree_leaves(st2.params)]
    for a, b in zip(total0, total1):
        np.testing.assert_allclose(a, b, atol=1e-4)
