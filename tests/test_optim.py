import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, cosine_schedule, momentum, sgd, warmup_cosine
from repro.optim.optimizers import apply_updates


def _quadratic():
    target = jnp.array([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["x"] - target) ** 2)

    return {"x": jnp.zeros(3)}, loss, target


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1),
    lambda: momentum(0.05, beta=0.9),
    lambda: momentum(0.05, beta=0.9, nesterov=True),
    lambda: adamw(0.1),
])
def test_converges_on_quadratic(make_opt):
    params, loss, target = _quadratic()
    opt = make_opt()
    state = opt.init(params)
    for step in range(400):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, jnp.asarray(step))
        params = apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(params["x"]), np.asarray(target), atol=1e-2)


def test_schedules():
    cos = cosine_schedule(1.0, 100, final_frac=0.1)
    assert float(cos(0)) == pytest.approx(1.0)
    assert float(cos(100)) == pytest.approx(0.1, abs=1e-6)
    wc = warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(wc(0)) == pytest.approx(0.0)
    assert float(wc(10)) == pytest.approx(1.0)
    assert float(wc(5)) == pytest.approx(0.5)


def test_adamw_weight_decay():
    opt = adamw(0.1, weight_decay=0.1)
    params = {"x": jnp.ones(2)}
    state = opt.init(params)
    g = {"x": jnp.zeros(2)}
    upd, state = opt.update(g, state, params, jnp.asarray(0))
    assert float(upd["x"][0]) < 0  # decay pulls toward zero
