"""Unified `repro.api` interface: registry + parity with the legacy paths.

The acceptance bar for the API redesign: `simulate(...)` must reproduce
the legacy `run_windows` (DRACO) and `run_baseline` (all four baselines)
results **bit-for-bit** on a fixed seed, while compiling once per
(algorithm, config)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Algorithm,
    get_algorithm,
    list_algorithms,
    make_context,
    simulate,
    steps_for_budget,
)
from repro.api.simulate import _run
from repro.core.baselines import (
    BASELINES,
    eval_params as legacy_eval_params,
    init_baseline_state,
    run_baseline,
)
from repro.core.channel import ChannelConfig
from repro.core.protocol import DracoConfig, build_graph, init_state, run_windows
from repro.data.synthetic import federated_classification, make_mlp

N = 5
ALL_METHODS = ("draco",) + tuple(BASELINES)


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    train, test = federated_classification(k1, N, input_dim=6, num_classes=3,
                                           per_client=64)
    params0, apply, loss, acc = make_mlp(k2, 6, (8,), 3)
    return train, test, params0, loss, acc


def _cfg(**kw):
    base = dict(num_clients=N, lr=0.1, local_batches=1, batch_size=8,
                lambda_grad=0.8, lambda_tx=0.8, unify_period=10, psi=2,
                topology="complete", max_delay_windows=3, channel=None)
    base.update(kw)
    return DracoConfig(**base)


def _assert_trees_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_registry_resolves_every_method():
    names = list_algorithms()
    for name in ALL_METHODS:
        algo = get_algorithm(name)
        assert name in names
        assert isinstance(algo, Algorithm)
        # singleton: jit-static identity is stable across lookups
        assert get_algorithm(name) is algo
    with pytest.raises(KeyError):
        get_algorithm("no-such-method")


@pytest.mark.slow
def test_draco_parity_bitwise(task):
    """simulate("draco", ...) == run_windows bit-for-bit, incl. wireless
    channel + Psi cap + unification, with in-jit eval enabled."""
    train, test, params0, loss, acc = task
    cfg = _cfg(channel=ChannelConfig(message_bytes=51_640, gamma_max=10.0))
    key = jax.random.PRNGKey(7)
    q, adj = build_graph(cfg)
    legacy = run_windows(init_state(key, cfg, params0), cfg, q, adj, loss,
                         train, 12)
    new, trace = simulate("draco", cfg, params0, loss, train, 12, key=key,
                          eval_every=4, eval_fn=acc, eval_data=test)
    _assert_trees_equal(legacy.params, new.params)
    _assert_trees_equal(legacy.pending, new.pending)
    _assert_trees_equal(legacy.buffer, new.buffer)
    np.testing.assert_array_equal(np.asarray(legacy.accept_count),
                                  np.asarray(new.accept_count))
    np.testing.assert_array_equal(np.asarray(legacy.total_accept),
                                  np.asarray(new.total_accept))
    # cumulative counter survives the periodic accept_count reset
    assert int(new.total_accept.sum()) >= int(new.accept_count.sum())
    assert int(legacy.window_idx) == int(new.window_idx) == 12
    assert list(trace.step) == [4, 8, 12]
    assert np.isfinite(trace.metrics["accuracy"]).all()
    assert (trace.metrics["consensus"] >= 0).all()


@pytest.mark.slow
@pytest.mark.parametrize("method", BASELINES)
def test_baseline_parity_bitwise(method, task):
    """simulate(method, ...) == run_baseline bit-for-bit for every
    registered baseline, and eval_params matches the legacy de-biasing."""
    train, _, params0, loss, _ = task
    cfg = _cfg(topology="cycle")
    key = jax.random.PRNGKey(11)
    legacy = run_baseline(method, init_baseline_state(key, cfg, params0),
                          cfg, loss, train, 10)
    new, _ = simulate(method, cfg, params0, loss, train, 10, key=key)
    _assert_trees_equal(legacy.params, new.params)
    np.testing.assert_array_equal(np.asarray(legacy.push_weight),
                                  np.asarray(new.push_weight))
    _assert_trees_equal(legacy_eval_params(method, legacy),
                        get_algorithm(method).eval_params(new))


def test_simulate_compiles_once_per_algo_cfg(task):
    """Re-running simulate with the same (algo, cfg, loss) hits the jit
    cache; a different cfg triggers exactly one new compile."""
    train, _, params0, loss, _ = task
    cfg = _cfg()
    key = jax.random.PRNGKey(3)
    simulate("draco", cfg, params0, loss, train, 3, key=key)
    n0 = _run._cache_size()
    simulate("draco", cfg, params0, loss, train, 3, key=key)
    assert _run._cache_size() == n0
    simulate("draco", cfg.replace(psi=3), params0, loss, train, 3, key=key)
    assert _run._cache_size() == n0 + 1


@pytest.mark.slow
def test_shared_context_reused_across_methods(task):
    """One SimContext drives every method (graph built once)."""
    train, _, params0, loss, _ = task
    cfg = _cfg(topology="cycle")
    ctx = make_context(cfg, loss, train)
    key = jax.random.PRNGKey(5)
    for name in ALL_METHODS:
        st, _ = simulate(name, cfg, params0, loss, train, 2, key=key, ctx=ctx)
        for leaf in jax.tree_util.tree_leaves(st.params):
            assert bool(jnp.isfinite(leaf).all()), name


def test_ctx_cfg_mismatch_guard(task):
    """A stale ctx.cfg raises; ctx.replace(cfg=...) shares the graph."""
    train, _, params0, loss, _ = task
    cfg = _cfg(topology="cycle")
    ctx = make_context(cfg, loss, train)
    key = jax.random.PRNGKey(9)
    cfg2 = cfg.replace(psi=1)
    with pytest.raises(ValueError, match="ctx.cfg"):
        simulate("draco", cfg2, params0, loss, train, 2, key=key, ctx=ctx)
    st, _ = simulate("draco", cfg2, params0, loss, train, 2, key=key,
                     ctx=ctx.replace(cfg=cfg2))
    assert int(st.window_idx) == 2


def test_steps_for_budget_compute_matching():
    cfg = _cfg(lambda_grad=0.1, window=1.0)
    p = 1.0 - np.exp(-0.1)
    budget = 100 * p  # DRACO's expected grads over 100 windows
    assert steps_for_budget("draco", cfg, budget) == 100
    assert steps_for_budget("sync-symm", cfg, budget) == max(1, round(budget))
    assert steps_for_budget("sync-push", cfg, budget) == max(1, round(budget))
    assert steps_for_budget("async-symm", cfg, budget) == max(1, round(budget / 0.5))
    assert steps_for_budget("async-push", cfg, budget) == max(1, round(budget / 0.5))


def test_eval_every_zero_skips_trace(task):
    train, _, params0, loss, _ = task
    cfg = _cfg()
    st, trace = simulate("draco", cfg, params0, loss, train, 4,
                         key=jax.random.PRNGKey(1))
    assert trace.step.shape == (0,)
    assert trace.metrics == {}
    assert int(st.window_idx) == 4


@pytest.mark.slow
def test_final_partial_chunk_eval_row(task):
    """`num_steps % eval_every` trailing steps end with a metrics row at
    step `num_steps`, so the trace reflects the end-of-run model (the
    pre-PR4 driver ran them metric-free and under-reported every run
    whose horizon wasn't a multiple of the cadence)."""
    train, test, params0, loss, acc = task
    cfg = _cfg()
    key = jax.random.PRNGKey(4)
    st, trace = simulate("draco", cfg, params0, loss, train, 10, key=key,
                         eval_every=4, eval_fn=acc, eval_data=test)
    assert list(trace.step) == [4, 8, 10]
    # the final row is measured on the returned final state
    final_acc = float(jax.vmap(lambda p: acc(p, test[0], test[1]))(
        st.params).mean())
    np.testing.assert_allclose(trace.metrics["accuracy"][-1], final_acc,
                               rtol=1e-6)
    # fewer steps than the cadence -> exactly one row, at num_steps
    st2, trace2 = simulate("draco", cfg, params0, loss, train, 3, key=key,
                           eval_every=4, eval_fn=acc, eval_data=test)
    assert list(trace2.step) == [3]


@pytest.mark.slow
def test_trace_step_dtype_unified(task):
    """SimTrace.step is int32 for empty, scanned, and appended rows."""
    train, test, params0, loss, acc = task
    cfg = _cfg()
    key = jax.random.PRNGKey(4)
    _, empty = simulate("draco", cfg, params0, loss, train, 2, key=key)
    assert empty.step.dtype == np.int32
    _, exact = simulate("draco", cfg, params0, loss, train, 8, key=key,
                        eval_every=4, eval_fn=acc, eval_data=test)
    assert exact.step.dtype == np.int32 and list(exact.step) == [4, 8]
    _, ragged = simulate("draco", cfg, params0, loss, train, 9, key=key,
                         eval_every=4, eval_fn=acc, eval_data=test)
    assert ragged.step.dtype == np.int32 and list(ragged.step) == [4, 8, 9]


def test_resume_from_state_without_key(task):
    """Resuming from an existing state needs no PRNGKey; two chained
    simulate calls equal one long run (scan is state-threaded)."""
    train, _, params0, loss, _ = task
    cfg = _cfg()
    key = jax.random.PRNGKey(2)
    full, _ = simulate("draco", cfg, params0, loss, train, 8, key=key)
    half, _ = simulate("draco", cfg, params0, loss, train, 4, key=key)
    resumed, _ = simulate("draco", cfg, params0, loss, train, 4, state=half)
    _assert_trees_equal(full.params, resumed.params)
    with pytest.raises(ValueError, match="key is required"):
        simulate("draco", cfg, params0, loss, train, 4)
