import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_reduced
from repro.models.attention import (
    KVCache,
    blocked_attention,
    decode_attention,
    full_attention,
    init_attention,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("stablelm-3b")
    key = jax.random.PRNGKey(0)
    params = init_attention(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model))
    return cfg, params, x


def test_blocked_matches_full(setup):
    cfg, params, x = setup
    full = full_attention(params, x, cfg)
    blocked = blocked_attention(params, x, cfg, block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                               atol=2e-4, rtol=2e-3)


def test_blocked_sliding_window_matches_full(setup):
    cfg, params, x = setup
    w = 24
    full = full_attention(params, x, cfg, sliding_window=w)
    blocked = blocked_attention(params, x, cfg, block_q=16, block_kv=16,
                                sliding_window=w)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(full),
                               atol=2e-4, rtol=2e-3)


def test_decode_matches_full(setup):
    cfg, params, x = setup
    B, S, _ = x.shape
    full = full_attention(params, x, cfg)
    cache = KVCache.init(B, S, cfg.num_kv_heads, cfg.resolved_head_dim, x.dtype)
    outs = []
    for t in range(S):
        o, cache = decode_attention(params, x[:, t : t + 1], cache, t, cfg)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-3)


def test_ring_cache_matches_windowed_full(setup):
    cfg, params, x = setup
    B, S, _ = x.shape
    w = 16
    full = full_attention(params, x, cfg, sliding_window=w)
    cache = KVCache.init(B, w, cfg.num_kv_heads, cfg.resolved_head_dim, x.dtype)
    outs = []
    for t in range(S):
        o, cache = decode_attention(params, x[:, t : t + 1], cache, t, cfg,
                                    ring=True)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=3e-4, rtol=3e-3)


def test_causality(setup):
    """Perturbing future tokens must not change past outputs."""
    cfg, params, x = setup
    y1 = full_attention(params, x, cfg)
    x2 = x.at[:, 40:].set(jax.random.normal(jax.random.PRNGKey(9), x[:, 40:].shape))
    y2 = full_attention(params, x2, cfg)
    np.testing.assert_allclose(np.asarray(y1[:, :40]), np.asarray(y2[:, :40]),
                               atol=1e-5)


def test_gqa_repeat_consistency():
    """kv=1 GQA equals kv=nq MHA when kv weights are tiled."""
    cfg1 = get_reduced("stablelm-3b").with_(num_heads=4, num_kv_heads=1)
    key = jax.random.PRNGKey(3)
    p1 = init_attention(key, cfg1)
    cfgN = cfg1.with_(num_kv_heads=4)
    pN = dict(p1)
    pN["wk"] = jnp.tile(p1["wk"], (1, 4))
    pN["wv"] = jnp.tile(p1["wv"], (1, 4))
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 32, cfg1.d_model))
    y1 = full_attention(p1, x, cfg1)
    yN = full_attention(pN, x, cfgN)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(yN), atol=2e-5, rtol=2e-4)
