"""Convergence-theory sanity checks against Theorem 1's structure.

We can't verify the constant factors, but we CAN check the qualitative
claims the bound encodes on a controllable strongly-convex problem:
  (i)  gradient norms shrink over time under the step-size condition
       gamma <= 1/(8 B L N Psi);
  (ii) the first bound term ~ F/(B gamma Psi): larger Psi (more accepted
       messages) does not hurt, tiny Psi slows convergence;
  (iii) client variance stays bounded (the unification term's job).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.protocol import DracoConfig, build_graph, init_state, run_windows

# tier-2: multi-hundred-window convergence-theory runs (ROADMAP tier-1
# runs -m "not slow")
pytestmark = pytest.mark.slow

N = 6
DIM = 10


def _quad_task(key):
    """Heterogeneous strongly-convex quadratics: f_i(x) = |x - c_i|^2/2.
    L = 1; global optimum = mean(c_i)."""
    cs = jax.random.normal(key, (N, DIM))
    # data = per-client targets packed as (xs, ys): reuse protocol's API
    xs = jnp.repeat(cs[:, None, :], 64, axis=1)  # (N, S, DIM): batches of c_i
    ys = jnp.zeros((N, 64), jnp.int32)

    def loss(p, bx, by):
        return 0.5 * jnp.mean(jnp.sum((p["x"][None, :] - bx) ** 2, axis=-1))

    params0 = {"x": jnp.zeros((DIM,))}
    c_bar = cs.mean(0)
    return params0, loss, cs, c_bar, (xs, ys)


def _global_grad_norm(params, cs):
    x_bar = params["x"].mean(0)
    g = x_bar - cs.mean(0)
    return float(jnp.linalg.norm(g))


def _run(psi, windows, key, lr=None):
    params0, loss, cs, c_bar, data = _quad_task(jax.random.fold_in(key, 0))
    B, L, Psi_eff = 1, 1.0, max(psi, 3)
    gamma_max = 1.0 / (8 * B * L * N * Psi_eff)
    cfg = DracoConfig(num_clients=N, lr=lr or gamma_max, local_batches=B,
                      batch_size=8, lambda_grad=0.9, lambda_tx=0.9,
                      unify_period=25, psi=psi, topology="complete",
                      max_delay_windows=2, channel=None)
    q, adj = build_graph(cfg)
    st = init_state(jax.random.fold_in(key, 1), cfg, params0)
    g0 = _global_grad_norm(st.params, cs)
    st = run_windows(st, cfg, q, adj, loss, data, windows)
    return g0, _global_grad_norm(st.params, cs), st, cs


def test_gradient_norm_decreases():
    key = jax.random.PRNGKey(0)
    g0, g1, _, _ = _run(psi=0, windows=600, key=key, lr=0.05)
    assert g1 < 0.5 * g0, (g0, g1)


def test_theorem_preconditions():
    # the bound needs N > 4 and Psi >= 3 — our default sim satisfies both
    assert N > 4
    g0, g1, _, _ = _run(psi=3, windows=400, key=jax.random.PRNGKey(1), lr=0.05)
    assert g1 < g0


def test_tiny_psi_slower_than_ample_psi():
    """Fig. 4 trend: psi=1 starves aggregation vs psi=N-1."""
    key = jax.random.PRNGKey(2)
    _, g_small, _, _ = _run(psi=1, windows=300, key=key, lr=0.05)
    _, g_large, _, _ = _run(psi=N - 1, windows=300, key=key, lr=0.05)
    assert g_large <= g_small * 1.5  # ample psi at least comparable


def test_client_variance_bounded_by_unification():
    key = jax.random.PRNGKey(3)
    _, _, st, cs = _run(psi=0, windows=500, key=key, lr=0.05)
    x = st.params["x"]  # (N, DIM)
    spread = float(jnp.linalg.norm(x - x.mean(0, keepdims=True), axis=-1).max())
    scale = float(jnp.linalg.norm(cs, axis=-1).mean())
    assert spread < scale  # local models stay clustered


def test_windowed_engine_converges_to_event_engine_as_window_shrinks():
    """Window->0 limit: the superposition-window discretization loses
    events (a window collapses multiple Poisson points into one mask
    bit, expected firings per unit time (1-exp(-lam w))/w < lam), so a
    coarse-window run converges *slower* than the exact timeline. As the
    window shrinks at fixed rates/horizon, the windowed engine's mean
    final distance to the optimum approaches `simulate_events`' within
    seed noise."""
    from repro.api import simulate
    from repro.events import simulate_events

    horizon, K = 10.0, 8
    params0, loss, cs, c_bar, data = _quad_task(jax.random.PRNGKey(42))

    def cfg_w(w):
        return DracoConfig(num_clients=N, lr=0.08, local_batches=1,
                           batch_size=8, lambda_grad=0.9, lambda_tx=0.9,
                           unify_period=0, psi=0, topology="complete",
                           max_delay_windows=3, channel=None, window=w)

    def dist(st):
        return float(jnp.linalg.norm(st.params["x"].mean(0) - c_bar))

    def mean_final(run):
        return float(np.mean([run(s) for s in range(K)]))

    windows = (1.0, 0.5, 0.25, 0.125)
    d_win = [
        mean_final(lambda s, w=w: dist(simulate(
            "draco", cfg_w(w), params0=params0, loss_fn=loss, data=data,
            num_steps=int(round(horizon / w)),
            key=jax.random.PRNGKey(100 + s))[0]))
        for w in windows
    ]
    d_ev = mean_final(lambda s: dist(simulate_events(
        "draco-event", cfg_w(1.0), params0=params0, loss_fn=loss, data=data,
        horizon=horizon, tape_seed=1000 + s,
        key=jax.random.PRNGKey(100 + s))[0]))

    errs = [abs(d - d_ev) for d in d_win]
    # the discretization gap is visible at w=1 and collapses by w=1/8
    # (probe: 0.120 -> 0.068 -> 0.029 -> 0.007 against seed noise ~0.03)
    assert errs[0] > 0.06, (errs, d_ev)
    assert errs[-1] < 0.4 * errs[0], (errs, d_ev)
    assert errs[-1] < 0.1, (errs, d_ev)
    # and the coarse-window runs sit *above* the exact timeline
    assert d_win[0] > d_ev
