"""Gossip Pallas kernel vs jnp oracle: shape/dtype sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.gossip.ops import gossip_mix
from repro.kernels.gossip.ref import gossip_mix_ref

SHAPES = [(4, 64), (16, 512), (25, 513), (32, 1000), (7, 129), (64, 2048)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_kernel_matches_oracle(shape, dtype):
    n, d = shape
    key = jax.random.PRNGKey(n * d)
    k1, k2 = jax.random.split(key)
    q = jax.nn.softmax(jax.random.normal(k1, (n, n)), axis=1)
    deltas = jax.random.normal(k2, (n, d)).astype(dtype)
    out = gossip_mix(q, deltas, interpret=True)
    ref = gossip_mix_ref(q, deltas)
    assert out.dtype == deltas.dtype
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 24), d=st.integers(1, 300), seed=st.integers(0, 2**16))
def test_kernel_property_random(n, d, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    q = jax.random.uniform(k1, (n, n))
    q = q / q.sum(1, keepdims=True)
    deltas = jax.random.normal(k2, (n, d))
    out = gossip_mix(q, deltas, interpret=True)
    ref = gossip_mix_ref(q, deltas)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_linearity():
    key = jax.random.PRNGKey(9)
    n, d = 8, 96
    q = jax.nn.softmax(jax.random.normal(key, (n, n)))
    a = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    b = jax.random.normal(jax.random.fold_in(key, 2), (n, d))
    lhs = gossip_mix(q, a + 2.0 * b, interpret=True)
    rhs = gossip_mix(q, a, interpret=True) + 2.0 * gossip_mix(q, b, interpret=True)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)


def test_row_stochastic_mass_distribution():
    """Each sender's delta is distributed with total weight 1 across
    receivers: column-summed output equals column-summed input."""
    key = jax.random.PRNGKey(11)
    n, d = 12, 64
    q = jax.random.uniform(key, (n, n))
    q = q - jnp.diag(jnp.diag(q))
    q = q / q.sum(1, keepdims=True)
    deltas = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    out = gossip_mix(q, deltas, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out.sum(0)), np.asarray(deltas.sum(0)), atol=1e-3)
