import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore, save


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "groups": {"0:attn": {"wq": jax.random.normal(k1, (4, 8))}},
        "embed": jax.random.normal(k2, (16, 4)).astype(jnp.bfloat16),
        "scalars": (jnp.float32(3.5), jnp.int32(7)),
    }


def test_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    save(d, 10, tree)
    save(d, 20, tree)
    assert latest_step(d) == 20
    restored = restore(d, tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_restore_specific_step(tmp_path):
    d = str(tmp_path / "ckpt")
    t1 = {"x": jnp.ones(3)}
    t2 = {"x": 2 * jnp.ones(3)}
    save(d, 1, t1)
    save(d, 2, t2)
    r1 = restore(d, t1, step=1)
    np.testing.assert_array_equal(np.asarray(r1["x"]), np.ones(3))


def test_latest_none(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None
