"""Fused gossip engine vs the seed per-bucket-einsum loop: bit-for-bit.

The acceptance bar for the PR 2 perf work (mirrors tests/test_api.py's
role for the API redesign): `draco_window` on the flat parameter plane —
payload ring + deferred delay-bucketed drain — must reproduce the seed
`draco_window_legacy` **exactly** at f32, window by window, across ring
depths, wireless channel on/off, the Psi cap, and unification. The drain
accumulates stored broadcasts oldest-first, which is the same f32
addition order the seed ring buffer used; anything weaker than
`assert_array_equal` here would hide a reordering bug.
"""
import jax
import numpy as np
import pytest

from repro.core.channel import ChannelConfig
from repro.core.protocol import (
    DracoConfig,
    build_graph,
    draco_window,
    draco_window_legacy,
    init_state,
    init_state_legacy,
    run_windows,
    run_windows_legacy,
)
from repro.data.synthetic import federated_classification, make_mlp

# tier-2: fused-vs-seed engine parity battery (ROADMAP tier-1 runs -m "not slow")
pytestmark = pytest.mark.slow

N = 5
CHANNEL = ChannelConfig(message_bytes=51_640, gamma_max=10.0)


@pytest.fixture(scope="module")
def task():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    train, test = federated_classification(k1, N, input_dim=6, num_classes=3,
                                           per_client=64)
    params0, apply, loss, acc = make_mlp(k2, 6, (8,), 3)
    return train, params0, loss


def _cfg(**kw):
    base = dict(num_clients=N, lr=0.1, local_batches=1, batch_size=8,
                lambda_grad=0.8, lambda_tx=0.8, unify_period=10, psi=2,
                topology="complete", max_delay_windows=3, channel=None)
    base.update(kw)
    return DracoConfig(**base)


def _flat(tree):
    return np.concatenate(
        [np.asarray(l).reshape(N, -1)
         for l in jax.tree_util.tree_leaves(tree)], axis=1)


def _assert_states_equal(legacy, fused):
    """Every observable of the two engines matches bit-for-bit."""
    np.testing.assert_array_equal(_flat(legacy.params), _flat(fused.params))
    np.testing.assert_array_equal(_flat(legacy.pending),
                                  np.asarray(fused.pending))
    np.testing.assert_array_equal(np.asarray(legacy.accept_count),
                                  np.asarray(fused.accept_count))
    np.testing.assert_array_equal(np.asarray(legacy.total_accept),
                                  np.asarray(fused.total_accept))
    assert int(legacy.window_idx) == int(fused.window_idx)
    np.testing.assert_array_equal(np.asarray(legacy.key),
                                  np.asarray(fused.key))


@pytest.mark.parametrize("D", [2, 4, 8])
def test_parity_across_ring_depths_wireless(task, D):
    """Window-by-window bitwise parity with the wireless channel: per-link
    multi-window delays populate several ring buckets."""
    train, params0, loss = task
    cfg = _cfg(max_delay_windows=D, channel=CHANNEL)
    q, adj = build_graph(cfg)
    key = jax.random.PRNGKey(D)
    sl = init_state_legacy(key, cfg, params0)
    sf = init_state(key, cfg, params0)
    step_l = jax.jit(lambda s: draco_window_legacy(s, cfg, q, adj, loss, train))
    step_f = jax.jit(lambda s: draco_window(s, cfg, q, adj, loss, train))
    for _ in range(2 * D + 5):
        sl, sf = step_l(sl), step_f(sf)
        _assert_states_equal(sl, sf)


def test_parity_no_channel_unit_delays(task):
    """Without the channel every message has delay 1: all but one delay
    bucket is empty, exercising the fused drain's bucket skipping."""
    train, params0, loss = task
    cfg = _cfg(max_delay_windows=8, channel=None, psi=0)
    q, adj = build_graph(cfg)
    key = jax.random.PRNGKey(1)
    sl = run_windows_legacy(init_state_legacy(key, cfg, params0), cfg, q, adj,
                            loss, train, 15)
    sf = run_windows(init_state(key, cfg, params0), cfg, q, adj, loss,
                     train, 15)
    _assert_states_equal(sl, sf)


def test_parity_through_unification_and_psi(task):
    """Hub broadcasts reset both engines identically; the Psi cap and its
    periodic accept-count reset stay in lockstep."""
    train, params0, loss = task
    cfg = _cfg(unify_period=4, psi=1, lambda_tx=2.0, channel=CHANNEL,
               max_delay_windows=4)
    q, adj = build_graph(cfg)
    key = jax.random.PRNGKey(2)
    sl = run_windows_legacy(init_state_legacy(key, cfg, params0), cfg, q, adj,
                            loss, train, 13)
    sf = run_windows(init_state(key, cfg, params0), cfg, q, adj, loss,
                     train, 13)
    _assert_states_equal(sl, sf)


def test_parity_apply_self_update(task):
    train, params0, loss = task
    cfg = _cfg(apply_self_update=True, max_delay_windows=4, channel=CHANNEL)
    q, adj = build_graph(cfg)
    key = jax.random.PRNGKey(3)
    sl = run_windows_legacy(init_state_legacy(key, cfg, params0), cfg, q, adj,
                            loss, train, 9)
    sf = run_windows(init_state(key, cfg, params0), cfg, q, adj, loss,
                     train, 9)
    _assert_states_equal(sl, sf)


def test_fused_buffer_holds_raw_payload_ring(task):
    """The fused ring stores the *raw* flat broadcast of each window (the
    seed stored already-mixed deltas): slot w % D == that window's
    pre-clear pending, and the in-flight mass reaches params only via
    later drains."""
    train, params0, loss = task
    cfg = _cfg(lambda_tx=100.0, lambda_grad=100.0, max_delay_windows=3,
               unify_period=0, psi=0)
    q, adj = build_graph(cfg)
    key = jax.random.PRNGKey(4)
    s0 = init_state(key, cfg, params0)
    step = jax.jit(lambda s: draco_window(s, cfg, q, adj, loss, train))
    s1 = step(s0)
    # slot 0 now holds window 0's broadcast payload = pending before the
    # post-send clear; with lambda_tx huge, pending after the clear is 0,
    # so reconstruct it from the drain that window 1 will apply.
    assert not np.asarray(s1.pending).any()
    payload = np.asarray(s1.buffer[0])
    assert np.abs(payload).sum() > 0  # grads fired with certainty
    # metadata rings carry that window's weights and unit delays
    np.testing.assert_array_equal(np.asarray(s1.delay_ring[0]),
                                  np.ones((N, N), np.int32))
    w0 = np.asarray(s1.w_ring[0])
    assert (w0 >= 0).all() and np.abs(w0).sum() > 0
    # the drain of window 1 delivers exactly w0^T @ payload
    s2 = step(s1)
    # (unify off; self-update off: params change only via arrivals)
    got = _flat(s2.params) - _flat(s1.params)
    want = w0.T @ payload
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_flat_pending_matches_legacy_layout(task):
    """fused.pending is exactly ravel(legacy.pending) on the flat plane."""
    train, params0, loss = task
    cfg = _cfg(lambda_tx=0.0, unify_period=0)  # backlogs only accumulate
    q, adj = build_graph(cfg)
    key = jax.random.PRNGKey(5)
    sl = run_windows_legacy(init_state_legacy(key, cfg, params0), cfg, q, adj,
                            loss, train, 6)
    sf = run_windows(init_state(key, cfg, params0), cfg, q, adj, loss,
                     train, 6)
    np.testing.assert_array_equal(_flat(sl.pending), np.asarray(sf.pending))
    assert np.abs(np.asarray(sf.pending)).sum() > 0