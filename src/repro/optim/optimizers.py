"""Minimal optimizer library (optax-free, pytree-based).

DRACO's paper uses plain SGD (Algorithm 1); momentum/AdamW are provided
for the production trainer and beyond-paper experiments. All states are
pytrees with the same client-stacked leading axis as the params, so the
gossip layer can mix them (or not) uniformly.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable  # params -> opt_state
    update: Callable  # (grads, opt_state, params, step) -> (updates, opt_state)


def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))

    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        w = jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, lr * w, cos(step - warmup))

    return fn


def _tree_scale(t, s):
    return jax.tree_util.tree_map(lambda x: (x.astype(jnp.float32) * s), t)


def sgd(schedule) -> Optimizer:
    schedule = schedule if callable(schedule) else constant_schedule(schedule)

    def init(params):
        return ()

    def update(grads, state, params, step):
        lr = schedule(step)
        return _tree_scale(grads, -lr), state

    return Optimizer(init, update)


def momentum(schedule, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    schedule = schedule if callable(schedule) else constant_schedule(schedule)

    def init(params):
        return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, m, params, step):
        lr = schedule(step)
        m = jax.tree_util.tree_map(lambda mm, g: beta * mm + g.astype(jnp.float32), m, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(
                lambda mm, g: -(lr * (beta * mm + g.astype(jnp.float32))), m, grads
            )
        else:
            upd = _tree_scale(m, -lr)
        return upd, m

    return Optimizer(init, update)


def adamw(schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    """AdamW whose bias-correction counter lives in its *own state*
    (`"t"`), not the caller's `step` argument.

    `step` feeds only the lr schedule — it is "protocol time" (the
    DRACO window / round index, shared by all clients), whereas bias
    correction must track how many updates *this* state has actually
    absorbed. A duty-cycled straggler whose first gradient event lands
    at window 100 still gets the full first-step correction
    (mhat = m/(1-b1) = g), instead of a ~(1-b1)-damped one keyed to a
    clock it never ticked.
    """
    schedule = schedule if callable(schedule) else constant_schedule(schedule)

    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "t": jnp.zeros((), jnp.float32),
        }

    def update(grads, state, params, step):
        lr = schedule(step)
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32), state["m"], grads
        )
        v = jax.tree_util.tree_map(
            lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(mm, vv, p):
            mhat = mm / bc1
            vhat = vv / bc2
            u = -lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u

        updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params, updates
    )
