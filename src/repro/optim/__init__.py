from repro.optim.optimizers import (
    Optimizer,
    adamw,
    cosine_schedule,
    constant_schedule,
    momentum,
    sgd,
    warmup_cosine,
)

__all__ = [
    "Optimizer",
    "adamw",
    "cosine_schedule",
    "constant_schedule",
    "momentum",
    "sgd",
    "warmup_cosine",
]
