"""Pure-jnp oracle for the SSD intra-chunk kernel."""
import jax.numpy as jnp


def ssd_chunk_ref(C, B, x, cums, dt):
    """Intra-chunk SSD, one (batch*head, chunk) slice at a time.

    C, B: (BH, nc, Q, N); x: (BH, nc, Q, P); cums, dt: (BH, nc, Q) f32.
    Returns:
      Y (BH, nc, Q, P): intra-chunk output
          Y[i] = sum_{j<=i} exp(cums_i - cums_j) (C_i . B_j) dt_j x_j
      S (BH, nc, N, P): end-of-chunk state contribution
          S = sum_j exp(cums_last - cums_j) dt_j B_j x_j^T
    """
    f32 = jnp.float32
    C, B, x = C.astype(f32), B.astype(f32), x.astype(f32)
    Q = C.shape[2]
    CB = jnp.einsum("zcqn,zckn->zcqk", C, B)  # (BH, nc, Qi, Qj)
    diff = cums[..., :, None] - cums[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None]
    L = jnp.exp(jnp.where(mask, diff, -1e30))  # mask pre-exp (overflow-safe)
    scores = CB * L * dt[..., None, :]
    Y = jnp.einsum("zcqk,zckp->zcqp", scores, x)
    decay_end = jnp.exp(cums[..., -1:] - cums) * dt  # (BH, nc, Q)
    S = jnp.einsum("zcq,zcqn,zcqp->zcnp", decay_end, B, x)
    return Y, S
