"""Pallas TPU kernel: Mamba2 SSD intra-chunk block.

The SSD "dual" form makes the intra-chunk computation an attention-like
pair of matmuls — exactly the MXU's sweet spot:

    scores = (C @ B^T) o exp(cums_i - cums_j) o dt_j   (Q x Q, masked)
    Y      = scores @ X                                 (Q x P)
    S      = (B * decay_dt)^T @ X                       (N x P)

Blocking: grid over (batch*heads, n_chunks); each step holds one chunk's
C/B (Q, N), X (Q, P) and the (Q, Q) score tile in VMEM. With the default
Q = 128, N = 128, P = 64 everything is lane/sublane aligned and the
working set is ~200 KB — far under the ~16 MB v5e VMEM, leaving room for
double buffering of the HBM streams.

The inter-chunk state recurrence (a tiny associative scan over n_chunks)
stays in JAX; it is O(T/Q) and bandwidth-trivial.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(c_ref, b_ref, x_ref, cums_ref, dt_ref, y_ref, s_ref):
    C = c_ref[0, 0].astype(jnp.float32)  # (Q, N)
    B = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    X = x_ref[0, 0].astype(jnp.float32)  # (Q, P)
    cums = cums_ref[0, 0].astype(jnp.float32)[:, 0]  # (Q,)
    dt = dt_ref[0, 0].astype(jnp.float32)[:, 0]  # (Q,)
    Q = C.shape[0]

    CB = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # (Qi, Qj)
    li = cums[:, None] - cums[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where(iota_j <= iota_i, li, -1e30))  # mask pre-exp
    scores = CB * L * dt[None, :]
    y_ref[0, 0] = jnp.dot(scores, X, preferred_element_type=jnp.float32).astype(y_ref.dtype)

    decay_dt = jnp.exp(cums[-1] - cums) * dt  # (Q,)
    Bw = B * decay_dt[:, None]
    s_ref[0, 0] = jnp.dot(Bw.T, X, preferred_element_type=jnp.float32).astype(s_ref.dtype)


def ssd_chunk_pallas(C, B, x, cums, dt, *, interpret: bool = False):
    """C/B (BH, nc, Q, N); x (BH, nc, Q, P); cums/dt (BH, nc, Q).

    Returns Y (BH, nc, Q, P) f32 and S (BH, nc, N, P) f32.
    """
    BH, nc, Qn, N = C.shape
    P = x.shape[-1]
    cums2 = cums[..., None]  # (BH, nc, Q, 1) — TPU wants >=2D trailing dims
    dt2 = dt[..., None]
    grid = (BH, nc)
    spec4 = lambda d3, d4: pl.BlockSpec((1, 1, d3, d4), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            spec4(Qn, N),
            spec4(Qn, N),
            spec4(Qn, P),
            spec4(Qn, 1),
            spec4(Qn, 1),
        ],
        out_specs=[spec4(Qn, P), spec4(N, P)],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Qn, P), jnp.float32),
            jax.ShapeDtypeStruct((BH, nc, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(C, B, x, cums2, dt2)
