"""jit'd wrapper: full SSD forward built on the Pallas intra-chunk kernel.

``ssd_forward_kernel(x, dt, A, B_, C_, D, chunk)`` mirrors
``repro.models.ssm.ssd_chunked`` semantics; the intra-chunk hot loop runs
in the Pallas kernel and the O(T/Q) inter-chunk state recurrence stays in
JAX (associative scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ref import ssd_chunk_ref
from repro.kernels.ssd.ssd import ssd_chunk_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_kernel"))
def ssd_forward_kernel(x, dt, A, B_, C_, D, *, chunk: int,
                       interpret: bool = False, use_kernel: bool = True):
    """x (B,T,H,P); dt (B,T,H); A (H,); B_/C_ (B,T,G,N); D (H,)."""
    Bb, T, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Q = chunk
    nc = T // Q
    f32 = jnp.float32

    # head-major (BH, nc, Q, .) layout
    xh = jnp.moveaxis(x, 2, 1).reshape(Bb * H, nc, Q, P)
    dth = jnp.moveaxis(dt, 2, 1).reshape(Bb * H, nc, Q).astype(f32)
    Bh = jnp.repeat(B_, rep, axis=2)
    Ch = jnp.repeat(C_, rep, axis=2)
    Bh = jnp.moveaxis(Bh, 2, 1).reshape(Bb * H, nc, Q, N)
    Ch = jnp.moveaxis(Ch, 2, 1).reshape(Bb * H, nc, Q, N)

    la = dth * jnp.repeat(A[None, :], Bb, 0).reshape(Bb * H)[:, None, None]
    cums = jnp.cumsum(la, axis=2)

    if use_kernel:
        Y_intra, S = ssd_chunk_pallas(Ch, Bh, xh, cums, dth, interpret=interpret)
    else:
        Y_intra, S = ssd_chunk_ref(Ch, Bh, xh, cums, dth)

    chunk_decay = jnp.exp(cums[:, :, -1])  # (BH, nc)

    def combine(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s1 * d2[..., None, None] + s2

    _, states = jax.lax.associative_scan(combine, (chunk_decay, S), axis=1)
    h_prev = jnp.concatenate([jnp.zeros_like(states[:, :1]), states[:, :-1]], axis=1)
    Y_inter = jnp.einsum("zcq,zcqn,zcnp->zcqp", jnp.exp(cums), Ch, h_prev)

    y = (Y_intra + Y_inter).reshape(Bb, H, T, P)
    y = jnp.moveaxis(y, 1, 2)  # (B,T,H,P)
    y = y + x.astype(f32) * D[None, None, :, None]
    return y.astype(x.dtype)
