"""Pallas TPU kernel: row-stochastic gossip aggregation.

Computes ``out = Q^T @ deltas`` for a small (N, N) mixing matrix Q and a
huge (N, D) stacked-update matrix (D = flattened parameter count /
tensor-parallel shard — hundreds of MB in production).

TPU-native blocking rationale:
  - D is tiled into ``block_d`` lanes (multiple of 128 to match the MXU
    lane width); each grid step streams one (N, block_d) tile of deltas
    HBM->VMEM, multiplies by the resident (N, N) Q tile on the MXU and
    writes one (N, block_d) output tile. Every delta byte moves exactly
    once — the kernel is purely memory-bound, matching its roofline role.
  - N (the client-axis, 16..64) is zero-padded to the 8-sublane multiple
    by the wrapper in ops.py; accumulation is f32 regardless of input
    dtype (bf16 deltas are common).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gossip_kernel(q_ref, d_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)  # (N, N) resident
    d = d_ref[...].astype(jnp.float32)  # (N, block_d)
    o_ref[...] = jnp.dot(
        q.T, d, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def gossip_mix_pallas(q, deltas, *, block_d: int = 512, interpret: bool = False):
    """q (N, N) f32; deltas (N, D) with D % block_d == 0 (padded by ops)."""
    n, d_total = deltas.shape
    assert q.shape == (n, n)
    assert d_total % block_d == 0, (d_total, block_d)
    grid = (d_total // block_d,)
    return pl.pallas_call(
        _gossip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # Q resident in VMEM
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, d_total), deltas.dtype),
        interpret=interpret,
    )(q, deltas)
