"""Pallas TPU kernel: row-stochastic gossip aggregation.

Computes ``out = Q^T @ deltas`` for a small (N, N) mixing matrix Q and a
huge (N, D) stacked-update matrix (D = flattened parameter count /
tensor-parallel shard — hundreds of MB in production).

TPU-native blocking rationale:
  - D is tiled into ``block_d`` lanes (multiple of 128 to match the MXU
    lane width); each grid step streams one (N, block_d) tile of deltas
    HBM->VMEM, multiplies by the resident (N, N) Q tile on the MXU and
    writes one (N, block_d) output tile. Every delta byte moves exactly
    once — the kernel is purely memory-bound, matching its roofline role.
  - N (the client-axis, 16..64) is zero-padded to the 8-sublane multiple
    by the wrapper in ops.py; accumulation is f32 regardless of input
    dtype (bf16 deltas are common).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gossip_kernel(q_ref, d_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)  # (N, N) resident
    d = d_ref[...].astype(jnp.float32)  # (N, block_d)
    o_ref[...] = jnp.dot(
        q.T, d, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def gossip_mix_pallas(q, deltas, *, block_d: int = 512, interpret: bool = False):
    """q (N, N) f32; deltas (N, K) with K % block_d == 0 (padded by ops)."""
    n, d_total = deltas.shape
    assert q.shape == (n, n)
    assert d_total % block_d == 0, (d_total, block_d)
    grid = (d_total // block_d,)
    return pl.pallas_call(
        _gossip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # Q resident in VMEM
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, d_total), deltas.dtype),
        interpret=interpret,
    )(q, deltas)


def _enqueue_kernel(w_ref, p_ref, o_ref):
    """One (N, block_d) pending tile -> all J delay-bucket outputs."""
    p = p_ref[...].astype(jnp.float32)  # read the tile from HBM exactly once
    for j in range(w_ref.shape[0]):  # static unroll: J small (D-1)
        w = w_ref[j].astype(jnp.float32)
        o_ref[j] = jnp.dot(w.T, p, preferred_element_type=jnp.float32).astype(
            o_ref.dtype
        )


def gossip_enqueue_pallas(w_stack, pending, *, block_d: int = 512,
                          interpret: bool = False, out_dtype=None):
    """Batched delay-bucketed mixing: ``out[j] = w_stack[j]^T @ pending``.

    w_stack (J, N, N) f32 — the per-bucket masked weights (Q ⊙ M_d),
    stacked and resident in VMEM; pending (N, K) with K % block_d == 0.
    Each (N, block_d) pending tile moves HBM->VMEM once and feeds all J
    bucket outputs, vs J separate full passes for per-bucket einsums.
    """
    j_total, n, _ = w_stack.shape
    n2, k_total = pending.shape
    assert n == n2 and w_stack.shape == (j_total, n, n)
    assert k_total % block_d == 0, (k_total, block_d)
    out_dtype = pending.dtype if out_dtype is None else out_dtype
    grid = (k_total // block_d,)
    return pl.pallas_call(
        _enqueue_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((j_total, n, n), lambda i: (0, 0, 0)),  # VMEM resident
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((j_total, n, block_d), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((j_total, n, k_total), out_dtype),
        interpret=interpret,
    )(w_stack, pending)


def _drain_kernel(w_ref, p_ref, o_ref):
    """Accumulate all J buckets' arrivals for one (N, block_d) tile."""
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for j in range(w_ref.shape[0]):  # static unroll; order = stack order
        w = w_ref[j].astype(jnp.float32)
        p = p_ref[j].astype(jnp.float32)  # each payload tile read once
        acc = acc + jnp.dot(w.T, p, preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def gossip_drain_pallas(w_stack, payloads, *, block_d: int = 512,
                        interpret: bool = False, out_dtype=jnp.float32):
    """Fused multi-window drain: ``out = sum_j w_stack[j]^T @ payloads[j]``.

    w_stack (J, N, M) f32 — senders x receivers, square (M == N) on the
    single-device path, rectangular when a client shard drains its
    N-senders slice against all M receivers (`ops.gossip_drain_sharded`);
    payloads (J, N, K) with K % block_d == 0 — one stored broadcast per
    ring slot, in *chronological* (oldest-first) order so the f32
    accumulation matches the seed ring-buffer order. Every payload byte
    moves HBM->VMEM exactly once per window. Returns (M, K).
    """
    j_total, n, m = w_stack.shape
    assert payloads.shape[:2] == (j_total, n)
    k_total = payloads.shape[2]
    assert k_total % block_d == 0, (k_total, block_d)
    grid = (k_total // block_d,)
    return pl.pallas_call(
        _drain_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((j_total, n, m), lambda i: (0, 0, 0)),  # VMEM resident
            pl.BlockSpec((j_total, n, block_d), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((m, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, k_total), out_dtype),
        interpret=interpret,
    )(w_stack, payloads)
