"""jit'd public wrapper for the gossip mixing kernel (padding + fallback)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gossip.gossip import gossip_mix_pallas
from repro.kernels.gossip.ref import gossip_mix_ref


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gossip_mix(q, deltas, *, block_d: int = 512, interpret: bool = False):
    """out = Q^T deltas with TPU-friendly padding. q (N,N), deltas (N,D)."""
    n, d = deltas.shape
    qp = _pad_to(_pad_to(q.astype(jnp.float32), 8, 0), 8, 1)
    dp = _pad_to(_pad_to(deltas, 8, 0), block_d, 1)
    out = gossip_mix_pallas(qp, dp, block_d=block_d, interpret=interpret)
    return out[:n, :d]


def gossip_mix_reference(q, deltas):
    return gossip_mix_ref(q, deltas)
