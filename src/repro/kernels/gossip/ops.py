"""jit'd public wrappers for the gossip kernels (padding, backend select).

Backend auto-selection (one policy for every wrapper):

  - ``use_kernel=None``  -> Pallas only on TPU; pure-XLA lowering elsewhere
    (the kernel path in ``interpret`` mode is a correctness tool, far too
    slow for CPU CI hot loops).
  - ``interpret=None``   -> interpret mode exactly when not on TPU, so
    explicitly requesting the kernel path off-TPU still works (tests),
    while on TPU the compiled kernel is actually exercised.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gossip.gossip import (
    gossip_drain_pallas,
    gossip_enqueue_pallas,
    gossip_mix_pallas,
)
from repro.kernels.gossip.ref import gossip_enqueue_ref, gossip_mix_ref


def default_interpret() -> bool:
    """Pallas interpret mode iff there is no TPU to compile for."""
    return jax.default_backend() != "tpu"


def default_use_kernel() -> bool:
    """Use the Pallas kernels only where they compile natively."""
    return jax.default_backend() == "tpu"


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def gossip_mix(q, deltas, *, block_d: int = 512, interpret=None):
    """out = Q^T @ deltas with TPU-friendly padding; q (N, N) and
    deltas (N, K) flat updates -> (N, K)."""
    if interpret is None:
        interpret = default_interpret()
    n, d = deltas.shape
    qp = _pad_to(_pad_to(q.astype(jnp.float32), 8, 0), 8, 1)
    dp = _pad_to(_pad_to(deltas, 8, 0), block_d, 1)
    out = gossip_mix_pallas(qp, dp, block_d=block_d, interpret=interpret)
    return out[:n, :d]


def gossip_mix_reference(q, deltas):
    """Pure-jnp oracle: q (N, N), deltas (N, K) -> Q^T @ deltas."""
    return gossip_mix_ref(q, deltas)


def gossip_enqueue(w_stack, pending, *, block_d: int = 512, use_kernel=None,
                   interpret=None, out_dtype=None):
    """Batched delay-bucketed mixing: ``out[j] = w_stack[j]^T @ pending``.

    This is the *eager* lowering of bucketed gossip — mix one broadcast
    into all J delay buckets at send time.  The production DRACO engine
    instead stores raw payloads and defers mixing to `gossip_drain`;
    `gossip_enqueue` is kept as the eager building block (and as the
    oracle structure the drain parity tests lean on) for protocols that
    want mixed-delta rings.

    w_stack (J, N, N): per-delay-bucket masked weights (Q ⊙ M_d) for all
    buckets j at once; pending (N, K) flat updates.  Returns (J, N, K).
    On TPU this is one Pallas grid pass reading each pending tile from
    HBM exactly once (stacked weights resident in VMEM); elsewhere a
    batched einsum.  f32 accumulation regardless of input dtype;
    ``out_dtype`` defaults to ``pending.dtype``.
    """
    if use_kernel is None:
        use_kernel = default_use_kernel()
    if not use_kernel:
        return gossip_enqueue_ref(w_stack, pending, out_dtype=out_dtype)
    if interpret is None:
        interpret = default_interpret()
    j, n, _ = w_stack.shape
    _, k = pending.shape
    wp = _pad_to(_pad_to(w_stack.astype(jnp.float32), 8, 1), 8, 2)
    pp = _pad_to(_pad_to(pending, 8, 0), block_d, 1)
    out = gossip_enqueue_pallas(
        wp, pp, block_d=block_d, interpret=interpret,
        out_dtype=pending.dtype if out_dtype is None else out_dtype)
    return out[:, :n, :k]


def gossip_drain(w_stack, ring, slots, *, block_d: int = 512, use_kernel=None,
                 interpret=None):
    """Fused delay-bucketed drain: ``sum_j w_stack[j]^T @ ring[slots[j]]``.

    w_stack (J, N, M): masked weights per stored broadcast, stacked
    oldest-first — square (M == N) on the single-device path,
    rectangular (a senders slice against all M receivers) under
    `gossip_drain_sharded`; ring (S, N, K): the payload ring buffer;
    slots (J,): ring rows aligned with ``w_stack`` (oldest first).
    Returns the f32 (M, K) aggregate of everything arriving this window.

    The f32 accumulation runs in chronological order, so the result is
    bit-for-bit what the seed ring buffer would have accumulated slot by
    slot.  The XLA fallback unrolls one small GEMM per stored broadcast
    and wraps each in ``lax.cond`` keyed on "does this bucket carry any
    edge at all" — empty delay buckets (the common case when the delay
    distribution does not fill the ring) cost neither FLOPs nor memory
    traffic, which is what makes deep ``D`` nearly free.  Skipping is
    exact: an all-zero weight bucket contributes an exact ±0 matrix.
    """
    if use_kernel is None:
        use_kernel = default_use_kernel()
    m = w_stack.shape[2]  # receivers (== senders except per-shard slices)
    k = ring.shape[2]
    j_total = w_stack.shape[0]
    if use_kernel:
        if interpret is None:
            interpret = default_interpret()
        payloads = ring[slots]  # (J, N, K) HBM gather, chronological order
        wp = _pad_to(_pad_to(w_stack.astype(jnp.float32), 8, 1), 8, 2)
        pp = _pad_to(_pad_to(payloads, 8, 1), block_d, 2)
        out = gossip_drain_pallas(wp, pp, block_d=block_d, interpret=interpret)
        return out[:m, :k]
    out = jnp.zeros((m, k), jnp.float32)
    for j in range(j_total):
        w_j = w_stack[j].astype(jnp.float32)

        def _acc(o, w_j=w_j, j=j):
            p = jax.lax.dynamic_index_in_dim(ring, slots[j], 0, keepdims=False)
            return o + jax.lax.dot(w_j.T, p.astype(jnp.float32))

        out = jax.lax.cond(jnp.any(w_j != 0), _acc, lambda o: o, out)
    return out


def gossip_drain_sharded(w_stack, ring, slots, mesh, client_axes, *,
                         block_d: int = 512, use_kernel=None, interpret=None):
    """Client-sharded drain: per-device tiles + one `psum_scatter`.

    The explicit `shard_map` lowering of the sweep engine's sharded
    gossip contraction: the payload ring is sharded over the *sender*
    axis (each device holds its clients' stored broadcasts), every
    device runs `gossip_drain` on its `(J, N_loc, N)` weight slice —
    the Pallas grid on TPU, the unrolled-GEMM fallback elsewhere — and a
    single ``lax.psum_scatter`` over the *receiver* axis both sums the
    per-device partials and leaves each device holding exactly its own
    clients' aggregate (no all-reduce, no gather).

    w_stack (J, N, N) and ring (S, N, K) are both sharded on their
    *sender* axis (axis 1) over `client_axes` (a mesh axis name or
    tuple, e.g. the `sharding/axes.py` "clients" rule) — each device
    holds a rectangular (J, N_loc, N) weight slice and its senders'
    payloads; slots (J,) is replicated. N must divide the client mesh
    size. Returns the (N, K) f32 aggregate, sharded on axis 0.

    The per-receiver sum is re-associated across devices (psum order),
    so the result matches `gossip_drain` up to f32 reduction order —
    exact when every sender bucket lives on one device.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.mixing import _resolve_shard_map

    shard_map = _resolve_shard_map()
    axes = client_axes if isinstance(client_axes, tuple) else (client_axes,)
    # one name for both roles: PartitionSpec entry and collective axis
    ax = axes if len(axes) > 1 else axes[0]
    ndev = 1
    for a in axes:
        ndev *= mesh.shape[a]
    n = ring.shape[1]
    if n % ndev:
        raise ValueError(f"client count {n} not divisible by mesh client "
                         f"size {ndev}")

    def body(w, r, s):
        # w (J, N_loc, N): this device's senders against all receivers
        partial_full = gossip_drain(w, r, s, block_d=block_d,
                                    use_kernel=use_kernel,
                                    interpret=interpret)  # (N, K)
        # sum partials across devices AND keep only our receiver rows
        return jax.lax.psum_scatter(partial_full, ax,
                                    scatter_dimension=0, tiled=True)

    # check_rep=False: pallas_call has no shard_map replication rule (the
    # kernel path would otherwise raise NotImplementedError); the output
    # spec is exact — psum_scatter leaves each device its receiver rows
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, ax, None), P(None, ax, None), P()),
                   out_specs=P(ax, None), check_rep=False)
    return fn(w_stack, ring, slots)
