"""Pure-jnp oracle for the gossip mixing kernel."""
import jax.numpy as jnp


def gossip_mix_ref(q, deltas):
    """out[m, :] = sum_n q[n, m] * deltas[n, :].

    q: (N, N) row-stochastic (sender, receiver), deltas: (N, D).
    Accumulation in f32, output in deltas.dtype.
    """
    out = jnp.einsum(
        "nm,nd->md", q.astype(jnp.float32), deltas.astype(jnp.float32)
    )
    return out.astype(deltas.dtype)
