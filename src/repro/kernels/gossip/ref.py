"""Pure-jnp oracles for the gossip kernels."""
import jax.numpy as jnp


def gossip_mix_ref(q, deltas):
    """out[m, :] = sum_n q[n, m] * deltas[n, :].

    q: (N, N) row-stochastic (sender, receiver), deltas: (N, K).
    Accumulation in f32, output in deltas.dtype.
    """
    out = jnp.einsum(
        "nm,nd->md", q.astype(jnp.float32), deltas.astype(jnp.float32)
    )
    return out.astype(deltas.dtype)


def gossip_enqueue_ref(w_stack, pending, out_dtype=None):
    """Batched delay-bucketed mix: out[j] = w_stack[j]^T @ pending.

    w_stack: (J, N, N) per-bucket masked weights (Q ⊙ M_d), pending:
    (N, K).  f32 accumulation; output dtype defaults to pending.dtype.
    """
    out = jnp.einsum(
        "jnm,nk->jmk", w_stack.astype(jnp.float32), pending.astype(jnp.float32)
    )
    return out.astype(pending.dtype if out_dtype is None else out_dtype)


def gossip_drain_ref(w_stack, payloads, out_dtype=jnp.float32):
    """Fused multi-window drain: out = sum_j w_stack[j]^T @ payloads[j].

    w_stack: (J, N, N), payloads: (J, N, K), stacked oldest-first.
    f32 accumulation.
    """
    out = jnp.einsum(
        "jnm,jnk->mk", w_stack.astype(jnp.float32), payloads.astype(jnp.float32)
    )
    return out.astype(out_dtype)
