from repro.data.synthetic import (
    classification_task,
    dirichlet_partition,
    federated_classification,
    lm_token_batches,
    make_mlp,
)

__all__ = [
    "classification_task",
    "dirichlet_partition",
    "federated_classification",
    "lm_token_batches",
    "make_mlp",
]
