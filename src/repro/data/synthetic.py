"""Synthetic data pipeline.

Real EMNIST / Poker-hand files are unavailable offline; we generate
class-conditional Gaussian-mixture tasks with matched dimensionality and
class counts, plus Dirichlet non-iid federated partitions — the paper's
claims being validated are *relative* (method ordering, Psi trends).

Also provides deterministic LM token streams for the production trainer.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import cross_entropy, dense_init


def classification_task(key, n_samples: int, input_dim: int, num_classes: int,
                        noise: float = 0.6, anchors=None):
    """Gaussian mixture: one anchor per class + noise. Returns (x, y, anchors)."""
    k1, k2, k3 = jax.random.split(key, 3)
    if anchors is None:
        anchors = jax.random.normal(k1, (num_classes, input_dim))
    y = jax.random.randint(k2, (n_samples,), 0, num_classes)
    x = anchors[y] + noise * jax.random.normal(k3, (n_samples, input_dim))
    return x, y, anchors


def dirichlet_partition(key, y, num_clients: int, num_classes: int,
                        alpha: float = 0.5, per_client: int = 1000):
    """Non-iid split: per-client class distribution ~ Dirichlet(alpha).

    Returns (num_clients, per_client) indices into the dataset (sampling
    with replacement from class pools weighted by the client's mixture)."""
    kd, ks = jax.random.split(key)
    props = jax.random.dirichlet(kd, alpha * jnp.ones((num_classes,)), (num_clients,))
    class_logp = jnp.log(jnp.maximum(props, 1e-9))  # (C, K)
    # per-sample logits per client: logp of its class
    sample_logits = class_logp[:, y]  # (C, n_samples)
    keys = jax.random.split(ks, num_clients)
    idx = jax.vmap(
        lambda k, lg: jax.random.categorical(k, lg, shape=(per_client,))
    )(keys, sample_logits)
    return idx


def federated_classification(key, num_clients: int, input_dim: int,
                             num_classes: int, per_client: int = 1000,
                             alpha: float = 0.5, test_size: int = 2000,
                             noise: float = 0.6):
    """Full federated task: per-client train shards + common test set."""
    kt, kp, ke = jax.random.split(key, 3)
    pool_x, pool_y, anchors = classification_task(kt, 20_000, input_dim, num_classes, noise)
    idx = dirichlet_partition(kp, pool_y, num_clients, num_classes, alpha, per_client)
    xs = pool_x[idx]  # (N, per_client, dim)
    ys = pool_y[idx]
    test_x, test_y, _ = classification_task(
        ke, test_size, input_dim, num_classes, noise, anchors=anchors
    )
    return (xs, ys), (test_x, test_y)


def lm_token_batches(key, num_clients: int, per_client: int, seq_len: int,
                     vocab: int):
    """Deterministic synthetic token shards (N, per_client, seq_len)."""
    return jax.random.randint(key, (num_clients, per_client, seq_len), 0, vocab)


# ---------------------------------------------------------------------------
# Paper-scale model (the ~0.57 MB CNN stand-in): 2-hidden-layer MLP
# ---------------------------------------------------------------------------


def make_mlp(key, input_dim: int, hidden: tuple, num_classes: int):
    dims = (input_dim,) + tuple(hidden) + (num_classes,)
    keys = jax.random.split(key, len(dims) - 1)
    params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = dense_init(keys[i], (a, b), a)
        params[f"b{i}"] = jnp.zeros((b,))
    n_layers = len(dims) - 1

    def apply(p, x):
        h = x
        for i in range(n_layers):
            h = h @ p[f"w{i}"] + p[f"b{i}"]
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h

    def loss(p, x, y):
        return cross_entropy(apply(p, x), y)

    def accuracy(p, x, y):
        return (apply(p, x).argmax(-1) == y).mean()

    return params, apply, loss, accuracy
