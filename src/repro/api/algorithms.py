"""DRACO and the four Sec. 5 baselines as registered `Algorithm` plugins.

Each plugin is a thin adapter over the legacy step functions in
`repro.core.protocol` / `repro.core.baselines`, so the unified
`simulate` driver is **bit-for-bit** equivalent to the legacy
`run_windows` / `run_baseline` paths (tests/test_api.py asserts this).
Push-sum de-biasing lives in `eval_params`, not in the step, matching
the paper's evaluation convention.
"""
from __future__ import annotations

import math


from repro.api.algorithm import register_algorithm
from repro.core import baselines as baselines_lib
from repro.core import protocol as protocol_lib
from repro.scenarios.base import Snapshot

# Partial-participation probability for the async baselines (the fig3
# compute-matching assumes this value; it is the legacy default).
P_ACTIVE = 0.5


def _view(ctx, t) -> Snapshot:
    """The step-`t` world: the scenario schedule's ring lookup when the
    context carries one, else the frozen t=0 graph (positions/rates None
    so step functions stay on the frozen path bit-for-bit)."""
    if ctx.schedule is None:
        return Snapshot(q=ctx.q, adj=ctx.adj, w_sym=ctx.w_sym)
    return ctx.schedule.at(t)


@register_algorithm("draco")
class Draco:
    """Paper Algorithm 1/2: decoupled Poisson grad/tx events, row-
    stochastic gossip with Psi cap, delay ring-buffer, unification."""

    # config fields the sweep engine may re-bind as traced scalars
    sweepable = ("lr", "lambda_grad", "lambda_tx", "psi")

    def init(self, key, cfg, params0, task=None):
        return protocol_lib.init_state(key, cfg, params0, task=task)

    def step(self, state, ctx):
        v = _view(ctx, state.window_idx)
        return protocol_lib.draco_window(
            state, ctx.cfg, v.q, v.adj, ctx.task, ctx.data,
            spec=ctx.flat_spec, positions=v.positions,
            compute_rate=v.compute_rate, tx_rate=v.tx_rate,
            overrides=ctx.overrides,
        )

    def eval_params(self, state):
        return state.params

    def grads_per_step(self, cfg):
        # P(>= 1 Poisson grad event in one superposition window)
        return 1.0 - math.exp(-cfg.lambda_grad * cfg.window)


class _Baseline:
    """Shared init for the four baselines (BaselineState + positions)."""

    # baselines consume cfg.lr only (via local_updates); the Poisson-rate
    # and Psi knobs are DRACO-specific
    sweepable = ("lr",)

    def init(self, key, cfg, params0, task=None):
        return baselines_lib.init_baseline_state(key, cfg, params0, task=task)

    @staticmethod
    def _lr(ctx):
        return None if ctx.overrides is None else ctx.overrides.lr

    def eval_params(self, state):
        return baselines_lib.eval_params(self.name, state)

    def grads_per_step(self, cfg):
        return 1.0


@register_algorithm("sync-symm")
class SyncSymm(_Baseline):
    """Synchronous D-SGD with symmetric Metropolis mixing."""

    def step(self, state, ctx):
        v = _view(ctx, state.round_idx)
        return baselines_lib.sync_symm_round(
            state, ctx.cfg, v.w_sym, v.adj, ctx.task, ctx.data,
            positions=v.positions, compute_rate=v.compute_rate,
            lr=self._lr(ctx),
        )


@register_algorithm("sync-push")
class SyncPush(_Baseline):
    """Synchronous push-sum over the directed graph (gradient push)."""

    def step(self, state, ctx):
        v = _view(ctx, state.round_idx)
        state, _ = baselines_lib.sync_push_round(
            state, ctx.cfg, v.adj, ctx.task, ctx.data,
            positions=v.positions, compute_rate=v.compute_rate,
            lr=self._lr(ctx),
        )
        return state


@register_algorithm("async-symm")
class AsyncSymm(_Baseline):
    """Async partial participation + symmetric mixing among survivors."""

    def step(self, state, ctx):
        v = _view(ctx, state.round_idx)
        return baselines_lib.async_symm_round(
            state, ctx.cfg, v.w_sym, v.adj, ctx.task, ctx.data,
            p_active=P_ACTIVE, positions=v.positions,
            compute_rate=v.compute_rate, lr=self._lr(ctx),
        )

    def grads_per_step(self, cfg):
        return P_ACTIVE


@register_algorithm("async-push")
class AsyncPush(_Baseline):
    """Async push-sum gossip (Digest-style half-mass pushes)."""

    def step(self, state, ctx):
        v = _view(ctx, state.round_idx)
        state, _ = baselines_lib.async_push_round(
            state, ctx.cfg, v.adj, ctx.task, ctx.data,
            p_active=P_ACTIVE, positions=v.positions,
            compute_rate=v.compute_rate, lr=self._lr(ctx),
        )
        return state

    def grads_per_step(self, cfg):
        return P_ACTIVE
