"""`simulate_sweep`: whole experiment grids in one compiled device call.

The paper's headline figures are statements about *sweeps* — seeds x
configs x scenarios — but driving `simulate()` from a Python loop pays
one dispatch per cell and one re-compile per config variant (every
distinct `DracoConfig` is a fresh static jit key). This module batches
the whole grid into a single compiled call built from three orthogonal
axes over the same `repro.api.simulate._run_body` nested scan:

  - **seed axis (vmap).** Per-seed states are init-stacked and the run
    is `jax.vmap`-ed over them. XLA batches the per-step GEMMs; row `k`
    of the result is bit-for-bit the solo `simulate()` run with seed `k`
    (enforced by tests/test_sweep.py).
  - **config axis (scan over traced overrides).** Grid configs may
    differ only in *sweepable* fields (`lr`, `lambda_grad`, `lambda_tx`,
    `psi`) — those are stacked into `(G,)` arrays and re-bound per grid
    row as traced scalars (`repro.core.protocol.Overrides`, carried on
    `ctx.overrides`), so an lr/Psi/lambda sweep shares ONE trace instead
    of compiling `G` variants.
  - **scenario axis (scan over stacked schedules).** A list of
    same-shape `repro.scenarios.Schedule`s is tree-stacked and sliced
    per grid row — churn/straggler sweeps ride the same scan.

Client-axis sharding: pass `mesh=` (e.g. `launch.mesh.make_sweep_mesh()`)
and the client axis `N` of the states and federated data shards is laid
out over the mesh's client axes (the `sharding/axes.py` `"clients"`
rule: `("data",)` single-pod, `("pod", "data")` multi-pod). XLA's SPMD
partitioner then tiles the gossip `Q^T @ payload` contractions per
device with one reduce-scatter on the receiver axis — the explicit
`shard_map` lowering of that contraction ships as
`repro.kernels.gossip.ops.gossip_drain_sharded` (per-device Pallas tiles
on TPU, one `psum_scatter`), and the auto-SPMD path is checked against
it in tests/test_sweep_mesh.py.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.algorithm import Algorithm, get_algorithm
from repro.api.context import SimContext, make_context
from repro.api.simulate import _run_body
from repro.core.protocol import Overrides

# Config fields the engine knows how to re-bind as traced scalars.  An
# algorithm additionally declares which of these it actually consumes
# via its `sweepable` attribute; sweeping a field an algorithm ignores
# would silently produce G identical rows, so it is rejected.
SWEEPABLE = ("lr", "lambda_grad", "lambda_tx", "psi")
_OVERRIDE_DTYPES = {"lr": jnp.float32, "lambda_grad": jnp.float32,
                    "lambda_tx": jnp.float32, "psi": jnp.int32}


class SweepTrace(NamedTuple):
    """Grid-shaped metric trace of one `simulate_sweep` call.

    `step` is shared by every cell (same cadence everywhere); each
    metric is `(G, K, num_evals)` — grid rows x seeds x eval points.
    """

    step: np.ndarray  # (num_evals,) int32
    metrics: Dict[str, np.ndarray]  # each (G, K, num_evals)


def stack_configs(cfg_grid: Sequence) -> tuple:
    """Split a config grid into (base_cfg, stacked `Overrides`).

    Every config must equal the first one after normalizing the
    `SWEEPABLE` fields; fields that actually vary are stacked into
    `(G,)` arrays, constant fields stay static (None override) so the
    compiled call specializes on them.
    """
    cfgs = list(cfg_grid)
    if not cfgs:
        raise ValueError("empty config grid")
    base = cfgs[0]
    varying = {}
    for f in SWEEPABLE:
        vals = [getattr(c, f) for c in cfgs]
        if any(v != vals[0] for v in vals):
            varying[f] = jnp.asarray(vals, _OVERRIDE_DTYPES[f])
    norm = {f: getattr(base, f) for f in varying}
    for i, c in enumerate(cfgs):
        if c.replace(**norm) != base:
            bad = [f for f in c.__dataclass_fields__
                   if f not in varying and getattr(c, f) != getattr(base, f)]
            raise ValueError(
                f"cfg_grid[{i}] differs from cfg_grid[0] in non-sweepable "
                f"field(s) {bad}; only {SWEEPABLE} can vary inside one "
                "compiled sweep — split the grid or loop host-side")
    return base, Overrides(**varying)


def stack_schedules(schedules: Sequence):
    """Tree-stack same-shape `Schedule`s along a new leading grid axis."""
    scheds = list(schedules)
    structs = {jax.tree_util.tree_structure(s) for s in scheds}
    if len(structs) > 1:
        raise ValueError(
            "schedules must share one pytree structure (same fields "
            f"present, same ring periods); got {len(structs)} distinct")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *scheds)


def _client_sharding(x, num_clients: int, mesh, client_ax, skip_leading=0):
    """NamedSharding laying the first client-sized dim (past the leading
    `skip_leading` axes) over the mesh client axes; replicated when no
    dim matches or the mesh size does not divide N."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.sharding.specs import filter_divisible

    axes = [None] * x.ndim
    for d in range(skip_leading, x.ndim):
        if x.shape[d] == num_clients:
            axes[d] = client_ax
            break
    spec = filter_divisible(P(*axes), x.shape, mesh)
    return NamedSharding(mesh, spec)


def shard_grid_inputs(states, data, num_clients: int, mesh):
    """Lay the client axis of seed-stacked states + federated data over
    the mesh ("clients" rule from `sharding/axes.py`). Returns sharded
    (states, data); sharding is layout only — results are unchanged up
    to f32 reduction order."""
    from repro.sharding.axes import default_rules

    client_ax = default_rules(mesh).rules["clients"]
    states = jax.tree_util.tree_map(
        lambda x: jax.device_put(
            x, _client_sharding(x, num_clients, mesh, client_ax,
                                skip_leading=1)), states)
    if data is not None:
        data = jax.tree_util.tree_map(
            lambda x: jax.device_put(
                x, _client_sharding(x, num_clients, mesh, client_ax)), data)
    return states, data


@partial(jax.jit, static_argnames=("algo", "num_steps", "eval_every",
                                   "eval_fn", "grid", "final_fn",
                                   "metric_name"))
def _run_sweep(algo, ctx, states, eval_data, num_steps: int, eval_every: int,
               eval_fn, overrides, schedules, grid: int, final_fn,
               metric_name: str = "accuracy"):
    """scan(config/scenario grid) x vmap(seeds) x `_run_body` — one XLA
    program for the whole grid. `final_fn` slims each final state before
    it is stacked across the grid (a (G, K, D, N, Dflat) ring buffer
    stack is pure waste when the caller only wants `total_accept`)."""

    def one_row(_, row):
        ov, sched = row
        ctx_g = ctx
        # repro-lint: disable-next-line=TRACED-PY-BRANCH(structural: iterating the Overrides NamedTuple and testing `is not None` reads trace-time pytree structure, never traced values)
        if any(f is not None for f in ov):
            ctx_g = ctx_g.replace(overrides=ov)
        if sched is not None:
            ctx_g = ctx_g.replace(schedule=sched)
        finals, trace = jax.vmap(
            lambda st: _run_body(algo, ctx_g, st, eval_data, num_steps,
                                 eval_every, eval_fn, metric_name))(states)
        if final_fn is not None:
            finals = final_fn(finals)
        return None, (finals, trace)

    _, out = jax.lax.scan(one_row, None, (overrides, schedules), length=grid)
    return out


def simulate_sweep(
    algo: Union[str, Algorithm],
    cfg_grid,
    params0=None,
    loss_fn: Optional[Callable] = None,
    data: Any = None,
    num_steps: int = 1,
    *,
    task=None,
    task_key=None,
    keys=None,
    key=None,
    num_seeds: int = 1,
    eval_every: int = 0,
    eval_fn: Optional[Callable] = None,
    eval_data: Any = None,
    ctx: Optional[SimContext] = None,
    graph_key=None,
    schedules=None,
    mesh=None,
    final_fn: Optional[Callable] = None,
):
    """Run a whole (config x scenario) x seed grid in one compiled call.

    Args:
      algo: registry name or `Algorithm` (one method per sweep; loop
        methods host-side — they are distinct compiled programs anyway).
      cfg_grid: one config, or a sequence differing only in `SWEEPABLE`
        fields the algorithm declares sweepable (`algo.sweepable`).
      params0 / loss_fn / data / num_steps: as in `simulate`.
      task / task_key: the (model x optimizer x dataset) workload, as in
        `simulate` — params0/data/eval default to the task's builders,
        the local optimizer state rides the flat plane on every seed
        row, and the trace metric takes the task's name ("perplexity"
        for tiny-lm). Sweeping `lr` re-seeds the task's lr schedule per
        grid row (the optimizer hyperparameter axis); the task must
        declare it in `task.sweepable`.
      keys: (K, ...) stacked PRNGKeys, one per seed row; or pass `key` +
        `num_seeds` to split one. Row `k` is bit-identical to a solo
        `simulate(..., key=keys[k])` on one device.
      eval_every / eval_fn / eval_data: in-jit metric cadence, as in
        `simulate` (incl. the final partial-chunk eval row).
      ctx: prebuilt base `SimContext`; its cfg must equal the grid's
        base config (rebind with `ctx.replace(cfg=...)`). Built from
        (base cfg, loss_fn, data) when omitted.
      graph_key: seeds random topologies when building the context.
      schedules: optional sequence of same-shape scenario `Schedule`s —
        the grid's scenario axis. Length must match `cfg_grid` when both
        sweep (a grid row re-binds config overrides AND its schedule).
      mesh: optional `jax.sharding.Mesh`; shards the client axis N of
        states/data over the mesh's client axes (see module docstring).
      final_fn: optional per-row reducer applied to the vmapped final
        states before grid stacking, e.g. ``lambda s: s.total_accept``
        — pass a module-level function (it is a static jit key).

    Returns:
      (finals, SweepTrace): `finals` is `final_fn`'s output (or the full
      states) with leading (G, K) axes; the trace metrics are
      (G, K, num_evals).
    """
    from repro.api.simulate import resolve_workload
    from repro.tasks import is_task

    if isinstance(algo, str):
        algo = get_algorithm(algo)
    cfgs = cfg_grid if isinstance(cfg_grid, (list, tuple)) else [cfg_grid]
    base, overrides = stack_configs(cfgs)
    # params0 always feeds the vmapped state init; data only feeds a
    # freshly-built ctx (a prebuilt one brings its own shards)
    task, workload, params0, data, eval_data = resolve_workload(
        base, task, task_key, loss_fn, params0, data, eval_data,
        need_params=True, need_data=ctx is None)
    swept = [f for f in SWEEPABLE if getattr(overrides, f) is not None]
    if len(cfgs) > 1 and not swept:
        raise ValueError(
            f"cfg_grid has {len(cfgs)} entries but no field varies — the "
            "sweep would scan identical rows; pass one config (seeds/"
            "schedules are separate axes)")
    unsupported = sorted(set(swept) - set(getattr(algo, "sweepable", ())))
    if unsupported:
        raise ValueError(
            f"{algo.name!r} does not consume override field(s) "
            f"{unsupported} (sweepable: {getattr(algo, 'sweepable', ())}); "
            "sweeping them would return identical rows")

    sched_stack = None
    if schedules is not None:
        schedules = list(schedules)
        sched_stack = stack_schedules(schedules)
    grid = max(len(cfgs), len(schedules) if schedules is not None else 1)
    if len(cfgs) not in (1, grid) or (
            schedules is not None and len(schedules) != grid):
        raise ValueError(
            f"grid axes disagree: {len(cfgs)} config(s) vs "
            f"{len(schedules)} schedule(s); a scanned axis must cover "
            "every grid row (use a ctx-carried schedule for a constant "
            "scenario)")

    if keys is None:
        if key is None:
            raise ValueError("pass keys=(K,...) or key= + num_seeds=")
        keys = jax.random.split(key, num_seeds)
    keys = jnp.asarray(keys)

    if ctx is None:
        ctx = make_context(base, workload, data, params0=params0,
                           graph_key=graph_key)
    elif ctx.cfg != base:
        raise ValueError(
            "ctx.cfg differs from the grid's base config; pass "
            "ctx.replace(cfg=cfg_grid[0]) to reuse a context")
    elif workload is not None and ctx.task != workload:
        # equality, not identity: equal Task instances (e.g. two
        # with_optimizer() copies) are the same static jit key
        raise ValueError(
            "ctx.task differs from the task/loss_fn argument; pass "
            "ctx.replace(task=...) to rebind the workload")
    if ctx.overrides is not None:
        raise ValueError("ctx already carries overrides; sweeps own them")
    if sched_stack is not None and ctx.schedule is not None:
        raise ValueError(
            "pass either schedules= or a ctx with a schedule, not both")
    if (is_task(ctx.task) and "lr" in swept
            and "lr" not in ctx.task.sweepable):
        # the built-in optimizers all honor the traced lr (the schedule
        # is re-seeded per grid row), but a custom task whose
        # make_optimizer ignores its lr argument must say so — its grid
        # rows would be silently identical
        raise ValueError(
            f"task {ctx.task.name!r} does not declare 'lr' sweepable "
            f"(task.sweepable={ctx.task.sweepable}): its make_optimizer "
            "does not consume the per-row lr override, so the grid rows "
            "would be identical")
    metric_name = "accuracy"
    if eval_fn is None and is_task(ctx.task) and eval_data is not None:
        eval_fn = ctx.task.eval_fn
    if is_task(ctx.task) and eval_fn is ctx.task.eval_fn:
        metric_name = ctx.task.metric_name
    if eval_fn is not None and eval_data is None:
        raise ValueError("eval_fn requires eval_data=(ex, ey)")

    states = jax.vmap(lambda k: algo.init(k, base, params0,
                                          task=ctx.task))(keys)
    if mesh is not None:
        states, shard_data = shard_grid_inputs(states, ctx.data,
                                               base.num_clients, mesh)
        ctx = ctx.replace(data=shard_data)

    finals, raw = _run_sweep(algo, ctx, states, eval_data, int(num_steps),
                             int(eval_every), eval_fn, overrides, sched_stack,
                             grid, final_fn, metric_name)
    if raw is None:
        return finals, SweepTrace(np.zeros((0,), np.int32), {})
    step = np.asarray(raw["step"][0, 0])
    metrics = {k: np.asarray(v) for k, v in raw.items() if k != "step"}
    return finals, SweepTrace(step, metrics)
