"""`repro.api` — the unified algorithm interface and simulation driver.

One simulator for DRACO and every baseline:

    from repro.api import get_algorithm, list_algorithms, simulate

    state, trace = simulate("draco", cfg, params0, loss, train, 600,
                            key=key, eval_every=100,
                            eval_fn=acc, eval_data=test)
    print(trace.metrics["accuracy"])   # sampled in-jit, no host loop

Workloads are first-class `repro.tasks.Task`s — model x local optimizer
x federated dataset:

    state, trace = simulate("draco", cfg, task="tiny-lm", num_steps=600,
                            key=key, eval_every=100)
    print(trace.metrics["perplexity"])

Whole experiment grids (seeds x configs x scenarios) batch into one
compiled call via `simulate_sweep` (see `repro.api.sweep`).

New methods register with `@register_algorithm("name")` and implement
`init/step/eval_params/grads_per_step` (see `repro.api.algorithm`);
new workloads register with `@register_task("name")` (see
`repro.tasks`).
"""
from repro.api.algorithm import (
    Algorithm,
    get_algorithm,
    list_algorithms,
    register_algorithm,
)
from repro.api.context import SimContext, make_context
from repro.api.simulate import (
    SimTrace,
    consensus_distance,
    simulate,
    steps_for_budget,
)
from repro.api.sweep import SweepTrace, simulate_sweep

# importing the module registers the built-in algorithms
from repro.api import algorithms  # noqa: F401

# the continuous-time event engine: registers draco-event /
# fedasync-gossip / event-triggered / fedasync-window and re-exports the
# timeline driver (repro.events defers its api imports, so this is
# cycle-free)
from repro.events import events_context, simulate_events  # noqa: E402

__all__ = [
    "Algorithm",
    "SimContext",
    "SimTrace",
    "algorithms",
    "consensus_distance",
    "events_context",
    "get_algorithm",
    "list_algorithms",
    "make_context",
    "register_algorithm",
    "simulate",
    "simulate_sweep",
    "simulate_events",
    "SweepTrace",
    "steps_for_budget",
]
