"""`SimContext`: the immutable per-run simulation context.

Bundles everything a protocol step needs besides its own state — the
gossip graph (boolean adjacency, row-stochastic Q, symmetric Metropolis
weights), the *task* (model + loss + eval metric + local optimizer; see
`repro.tasks`), the federated data shards, the flat-plane layout
(`FlatSpec`: per-leaf shapes/offsets into the contiguous (N, Dflat)
buffer plus the (N, Dopt) optimizer plane, computed once per run),
optional node positions, and an optional scenario `schedule`
(`repro.scenarios.Schedule`: precomputed rings of time-varying
`(q_t, adj_t, positions_t, compute_rate_t)`, indexed by
``step % period`` inside the jitted scan) — so graph/channel/schedule
construction happens **once** per run instead of once per method (the
legacy `run_baseline` rebuilt the graph inside every jit).

`SimContext` is registered as a pytree: `(q, adj, w_sym, data,
positions, schedule, overrides, tape)` are traced children, while
`(cfg, task, flat_spec)` ride as static aux data. The `tape` slot
carries a `repro.events.EventTape` for the continuous-time event
engine (None everywhere else); like the schedule, it is device data —
equal-capacity tapes share one compiled scan. Passing a context through
`jax.jit` therefore recompiles only when the config, task, parameter
layout or schedule *structure* changes, exactly like the legacy
`static_argnames=("cfg", "loss_fn")` entry points.

Legacy shim: the `task` slot accepts either a `repro.tasks.Task` or a
bare ``loss(params, x, y)`` callable — pre-task call sites
(`make_context(cfg, loss_fn, data)`) keep working bit-for-bit, and
`ctx.loss_fn` always exposes the bare callable view.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Union

import jax

from repro.core import channel as channel_lib
from repro.core import flat as flat_lib
from repro.core.channel import ChannelConfig
from repro.core.protocol import build_graph
from repro.core.topology import metropolis


@jax.tree_util.register_pytree_node_class
class SimContext:
    """Immutable bundle of (cfg, task, q, adj, w_sym, data, positions,
    flat_spec, schedule, overrides, tape).

    `task` is the workload: a `repro.tasks.Task` or — the legacy shim —
    a bare loss callable (plain SGD). `overrides` is a
    `repro.core.protocol.Overrides` of traced config re-bindings
    (lr/lambda/psi), set per grid row by the sweep engine; None (the
    default everywhere else) is the plain static-config path.
    """

    __slots__ = ("cfg", "task", "q", "adj", "w_sym", "data", "positions",
                 "flat_spec", "schedule", "overrides", "tape")

    def __init__(self, cfg, task, q, adj, w_sym, data, positions=None,
                 flat_spec=None, schedule=None, overrides=None, tape=None):
        object.__setattr__(self, "cfg", cfg)
        object.__setattr__(self, "task", task)
        object.__setattr__(self, "q", q)
        object.__setattr__(self, "adj", adj)
        object.__setattr__(self, "w_sym", w_sym)
        object.__setattr__(self, "data", data)
        object.__setattr__(self, "positions", positions)
        object.__setattr__(self, "flat_spec", flat_spec)
        object.__setattr__(self, "schedule", schedule)
        object.__setattr__(self, "overrides", overrides)
        object.__setattr__(self, "tape", tape)

    def __setattr__(self, name, value):
        raise AttributeError("SimContext is immutable")

    @property
    def loss_fn(self):
        """The bare loss callable view of the task (legacy accessor)."""
        t = self.task
        return t.loss_fn if hasattr(t, "loss_fn") else t

    def replace(self, **kw) -> "SimContext":
        if "loss_fn" in kw:  # legacy field name keeps working
            kw["task"] = kw.pop("loss_fn")
        fields = {s: getattr(self, s) for s in self.__slots__}
        fields.update(kw)
        return SimContext(**fields)

    def tree_flatten(self):
        children = (self.q, self.adj, self.w_sym, self.data, self.positions,
                    self.schedule, self.overrides, self.tape)
        aux = (self.cfg, self.task, self.flat_spec)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        cfg, task, flat_spec = aux
        q, adj, w_sym, data, positions, schedule, overrides, tape = children
        return cls(cfg, task, q, adj, w_sym, data, positions, flat_spec,
                   schedule, overrides, tape)

    def __repr__(self):
        n = self.q.shape[0] if self.q is not None else "?"
        sched = ""
        if self.schedule is not None:
            sched = f", schedule_period={self.schedule.period}"
        t = self.task
        tname = getattr(t, "name", None) or getattr(t, "__name__", t)
        return (f"SimContext(n={n}, topology={getattr(self.cfg, 'topology', '?')}, "
                f"task={tname!r}{sched})")


def make_context(cfg, loss_fn: Optional[Union[Callable, str, Any]] = None,
                 data: Any = None, *, task=None, params0: Any = None,
                 graph_key=None, place_key=None, scenario=None,
                 scenario_key=None, scenario_kwargs=None) -> SimContext:
    """Build a `SimContext` from a `DracoConfig`-style config.

    Constructs the adjacency once and derives both weight matrices from
    it: row-stochastic Q (DRACO, push methods) and symmetric Metropolis
    weights (the *-symm baselines). `params0`, when given, fixes the
    flat parameter plane layout (`FlatSpec` shapes/offsets, plus the
    optimizer-plane width `opt_dim` when the workload is a task) once
    per run. `graph_key` seeds random topologies (e.g. "erdos");
    `place_key`, when given, additionally samples node positions for
    the wireless channel model (methods that carry positions in their
    own state may ignore it).

    The workload slot: pass `task=` (a `repro.tasks.Task` or registry
    name like ``"tiny-lm"``), or — the legacy shim — a bare loss
    callable in the `loss_fn` position. The two spellings may not
    disagree; a bare callable keeps the pre-task plain-SGD compiled
    path bit-for-bit.

    `scenario` (a `repro.scenarios` generator name or a prebuilt
    `Schedule`) attaches time-varying rings: the context's `q`/`adj`/
    `w_sym` become the schedule's step-0 snapshot and step functions
    read step-`t` graphs/rates via `ctx.schedule.at(t)`. `scenario_key`
    seeds the generator (defaults to `graph_key`, so a "static" scenario
    reproduces the frozen graph bit-for-bit); `scenario_kwargs` are the
    generator's knobs (churn rate, mobility speed, straggler fraction,
    ...).
    """
    from repro.tasks import get_task, is_task, opt_width

    if task is not None and loss_fn is not None and task is not loss_fn:
        raise ValueError("pass the workload as either task= or the loss_fn "
                         "position, not both")
    task = task if task is not None else loss_fn
    if isinstance(task, str):
        task = get_task(task)
    schedule = None
    if scenario is None:
        if scenario_key is not None or scenario_kwargs:
            # a forgotten scenario= would otherwise run the frozen graph
            # and silently produce frozen-graph numbers for a churn sweep
            raise ValueError(
                "scenario_key/scenario_kwargs given without scenario=")
        q, adj = build_graph(cfg, key=graph_key)
        w_sym = metropolis(adj)
    else:
        from repro.scenarios import make_schedule

        key = scenario_key if scenario_key is not None else graph_key
        schedule = make_schedule(scenario, cfg, key=key,
                                 **(scenario_kwargs or {}))
        if schedule.num_clients != cfg.num_clients:
            raise ValueError(
                f"schedule is for {schedule.num_clients} clients, "
                f"cfg.num_clients={cfg.num_clients}")
        q, adj, w_sym = schedule.q[0], schedule.adj[0], schedule.w_sym[0]
    positions = None
    if place_key is not None:
        positions = channel_lib.place_nodes(
            place_key, cfg.num_clients, cfg.channel or ChannelConfig()
        )
    flat_spec = None
    if params0 is not None:
        flat_spec = flat_lib.spec_for(params0, cfg.num_clients)
        if is_task(task):
            flat_spec = flat_spec.with_opt(opt_width(task, params0))
    return SimContext(cfg, task, q, adj, w_sym, data, positions, flat_spec,
                      schedule)
