"""The unified `Algorithm` interface and its string-keyed registry.

Every decentralized-learning method in this repo — DRACO itself and the
paper's four Sec. 5 baselines — is exposed as an `Algorithm`: three pure
functions over an opaque per-method state plus a compute-budget rate.
The shared `repro.api.simulate` driver runs any of them inside a single
`jax.lax.scan`, so a new protocol is a ~50-line plugin:

    @register_algorithm("my-method")
    class MyMethod:
        def init(self, key, cfg, params0,
                 task=None): ...                 # task: repro.tasks.Task
        def step(self, state, ctx): ...          # ctx: SimContext
        def eval_params(self, state): ...        # (N, ...) eval view
        def grads_per_step(self, cfg): ...       # expected local grads
                                                 #   per client per step

Registry instances are singletons: `get_algorithm(name)` always returns
the same object, so `jax.jit` with the algorithm as a static argument
compiles once per (algorithm, config).
"""
from __future__ import annotations

from typing import Any, Dict, Protocol, Tuple, runtime_checkable


@runtime_checkable
class Algorithm(Protocol):
    """Structural interface every registered method implements.

    `init(key, cfg, params0, task=None)` replicates a single-client
    pytree into the method's state (`task`, a `repro.tasks.Task`, sizes
    the flat local-optimizer plane); `step(state, ctx)` advances one
    round/window using only `state` and the immutable `SimContext`;
    `eval_params(state)` returns the (N, ...) parameter view metrics
    should be computed on (push-sum methods de-bias here);
    `grads_per_step(cfg)` is the expected number of local gradient
    events per client per step, used by `steps_for_budget` for
    compute-matched comparisons.
    """

    name: str

    def init(self, key, cfg, params0, task=None) -> Any:
        ...

    def step(self, state, ctx) -> Any:
        ...

    def eval_params(self, state) -> Any:
        ...

    def grads_per_step(self, cfg) -> float:
        ...


_REGISTRY: Dict[str, Algorithm] = {}


def register_algorithm(name: str):
    """Class decorator: instantiate once and register under `name`."""

    def deco(cls):
        algo = cls() if isinstance(cls, type) else cls
        algo.name = name
        _REGISTRY[name] = algo
        return cls

    return deco


def get_algorithm(name: str) -> Algorithm:
    """Resolve a registered algorithm (always the same singleton)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def list_algorithms() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
