"""Unified compiled simulation driver for any registered `Algorithm`.

`simulate(algo, cfg, params0, loss_fn, data, num_steps, ...)` runs the
whole protocol inside **one** compiled nested scan with *in-jit* metric
sampling: the outer scan walks the eval points, each inner scan runs
`eval_every` protocol steps and then computes the metric dict (mean
client accuracy on a held-out set, consensus distance) directly on
device, so there are no per-segment host round-trips, no re-dispatch,
and no per-step trace memory — one compile per (algorithm, config,
loss), then a single device call regardless of how often you sample.

`steps_for_budget` converts a compute budget (expected local gradient
events per client, priced at `task.grad_cost` when a task is given)
into a step count for any algorithm, expressing the paper's
compute-matched comparisons in one place.

Workloads are first-class: `simulate(algo, cfg, task="tiny-lm", ...)`
pulls model/data/optimizer/metric from the `repro.tasks` registry —
`params0` and `data` are built from the task when omitted, the local
optimizer state rides the flat plane, and the trace metric is named by
the task ("accuracy", "perplexity"). Bare `loss_fn=` callables remain
the legacy plain-SGD spelling, bit-for-bit.

Time-varying workloads ride the same scan: `simulate(...,
scenario="random-waypoint")` attaches a `repro.scenarios.Schedule` to
the context, and the per-step algorithm adapters index its rings by the
state-carried step counter — no extra scan carry, no recompilation per
step, and `scenario="static"` is bit-for-bit the frozen-graph path.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.algorithm import Algorithm, get_algorithm
from repro.api.context import SimContext, make_context


class SimTrace(NamedTuple):
    """In-jit metric trace, sized to the sampled steps on device.

    `step[k]` is the 1-indexed step count after which `metrics[...][k]`
    was measured; empty arrays when `eval_every == 0`. The arrays have
    `num_steps // eval_every` rows, plus one final row at `num_steps`
    when `num_steps % eval_every != 0` — the trace always reflects the
    end-of-run model (see `_run`). `step` is int32 everywhere (empty and
    scanned traces alike).
    """

    step: np.ndarray  # (num_evals,) int32
    metrics: Dict[str, np.ndarray]  # each (num_evals,) float


def consensus_distance(params) -> jax.Array:
    """RMS distance of per-client params to the virtual global model:
    sqrt(mean_i ||x_i - x_bar||^2) over all coordinates (Sec. 2.1).

    Computed on the flat parameter plane: one (N, Dflat) ravel and a
    single fused reduction instead of a per-leaf loop."""
    from repro.core import flat as flat_lib

    x = flat_lib.ravel_clients(params)
    xbar = x.mean(axis=0, keepdims=True)
    return jnp.sqrt(((x - xbar) ** 2).sum() / x.shape[0])


def _metrics(algo, state, eval_fn, eval_data, metric_name="accuracy"):
    p = algo.eval_params(state)
    out = {"consensus": consensus_distance(p)}
    if eval_fn is not None:
        ex, ey = eval_data
        out[metric_name] = jax.vmap(lambda pi: eval_fn(pi, ex, ey))(p).mean().astype(jnp.float32)
    return out


def _run_body(algo, ctx, state, eval_data, num_steps: int, eval_every: int,
              eval_fn, metric_name: str = "accuracy"):
    """One fused scan over `num_steps` protocol steps + in-jit eval.

    Nested scan: an outer scan over the `num_steps // eval_every` eval
    points, each running `eval_every` protocol steps inline and emitting
    one metrics row — so the device trace is `(num_evals,)` rather than
    a dense `(num_steps,)` carry that is mostly thrown away host-side
    (the pre-PR2 `lax.cond` sampling traced every step: ~8 bytes/metric/
    step of wasted HBM and a scan carry that grew with the eval cadence
    ignored). The `num_steps % eval_every` leftover steps past the last
    eval point run in a trailing metric-free scan followed by one final
    metrics row at step `num_steps`, so the trace always reflects the
    end-of-run model.

    Un-jitted on purpose: `_run` wraps it for solo `simulate` calls, and
    `repro.api.sweep` nests it under vmap (seed axis) and scan (config
    axis) inside its own jit."""

    def step_only(s, _):
        return algo.step(s, ctx), None

    if eval_every <= 0:
        state, _ = jax.lax.scan(step_only, state, None, length=num_steps)
        return state, None

    chunks, rem = divmod(num_steps, eval_every)

    def chunk_body(s, k):
        s, _ = jax.lax.scan(step_only, s, None, length=eval_every)
        m = _metrics(algo, s, eval_fn, eval_data, metric_name)
        return s, dict(m, step=(k + 1) * eval_every)

    state, trace = jax.lax.scan(chunk_body, state,
                                jnp.arange(chunks, dtype=jnp.int32))
    if rem:
        state, _ = jax.lax.scan(step_only, state, None, length=rem)
        last = dict(_metrics(algo, state, eval_fn, eval_data, metric_name),
                    step=jnp.asarray(num_steps, jnp.int32))
        trace = jax.tree_util.tree_map(
            lambda rows, row: jnp.concatenate(
                [rows, row[None].astype(rows.dtype)]), trace, last)
    return state, trace


_run = jax.jit(_run_body,
               static_argnames=("algo", "num_steps", "eval_every", "eval_fn",
                                "metric_name"))


def simulate(
    algo: Union[str, Algorithm],
    cfg,
    params0=None,
    loss_fn: Optional[Callable] = None,
    data: Any = None,
    num_steps: int = 1,
    *,
    task=None,
    task_key=None,
    key=None,
    eval_every: int = 0,
    eval_fn: Optional[Callable] = None,
    eval_data: Any = None,
    ctx: Optional[SimContext] = None,
    state: Any = None,
    graph_key=None,
    scenario=None,
    scenario_key=None,
    scenario_kwargs=None,
):
    """Run `num_steps` of any registered algorithm in one compiled call.

    Args:
      algo: registry name (e.g. "draco", "sync-push") or an `Algorithm`.
      cfg: `DracoConfig`-style frozen config (static: hashable).
      params0: single-client param pytree (ignored when `state` given;
        built by the task's model init when omitted and `task=` given).
      loss_fn: `loss(params_i, x, y)` used by local SGD (static). The
        legacy workload spelling — a `Task` supersedes it.
      data: federated train shards `(xs, ys)` with leading client axis
        (built by the task's dataset builder when omitted and `task=`
        given).
      num_steps: protocol steps (DRACO windows / baseline rounds).
      task: `repro.tasks.Task` or registry name ("linear-softmax",
        "mlp", "small-cnn", "tiny-lm"): the (model x optimizer x
        dataset) workload. Its local optimizer state rides the flat
        plane on the algorithm state; its `eval_fn`/`metric_name` are
        used when `eval_fn` is omitted. The default "linear-softmax" +
        sgd(constant) task is bit-for-bit the bare-`loss_fn` path.
      task_key: PRNGKey seeding the task's model/data builders when
        params0/data are omitted (defaults to PRNGKey(0), so repeated
        calls see the same workload).
      key: PRNGKey for state init (required unless `state` is given).
      eval_every: sample metrics every k steps, on device, via a nested
        scan that materializes one metrics row per sample (the trace is
        `(num_steps // eval_every,)`, plus a final row at `num_steps`
        when the division leaves a remainder — nothing is traced at the
        other steps); 0 disables in-jit eval entirely.
      eval_fn: `metric(params_i, ex, ey) -> scalar` (e.g. accuracy);
        vmapped over clients and averaged. Requires `eval_data`.
      eval_data: held-out `(ex, ey)` for `eval_fn`.
      ctx: prebuilt `SimContext` to share graph/channel/schedule
        construction across runs; built from (cfg, loss_fn, data) when
        omitted.
      state: resume from an existing algorithm state.
      graph_key: PRNGKey for random topologies (passed to `make_context`).
      scenario: `repro.scenarios` generator name (e.g.
        "markov-edge-flip") or prebuilt `Schedule` — attaches
        time-varying `(q_t, adj_t, positions_t, compute_rate_t)` rings.
        The scan itself carries no extra schedule index: each method's
        state already counts steps (`window_idx`/`round_idx`) and the
        per-step adapter looks up `schedule.at(step)` in-jit. Only valid
        when `ctx` is omitted (a prebuilt ctx brings its own schedule).
      scenario_key / scenario_kwargs: generator seed and knobs
        (see `make_context`).

    Returns:
      (final_state, SimTrace) — the trace holds exactly the sampled
      steps (sized on device; no host-side filtering).
    """
    from repro.tasks import is_task

    if isinstance(algo, str):
        algo = get_algorithm(algo)
    # params0 feeds state init and the ctx flat-spec (a warm restart with
    # a prebuilt ctx needs neither); data feeds the ctx (a prebuilt ctx
    # brings its own shards)
    task, workload, params0, data, eval_data = resolve_workload(
        cfg, task, task_key, loss_fn, params0, data, eval_data,
        need_params=state is None or ctx is None, need_data=ctx is None)
    if ctx is None:
        ctx = make_context(cfg, workload, data, params0=params0,
                           graph_key=graph_key, scenario=scenario,
                           scenario_key=scenario_key,
                           scenario_kwargs=scenario_kwargs)
    elif scenario is not None:
        raise ValueError(
            "pass scenario to make_context when prebuilding ctx; a ctx "
            "already carries its schedule")
    elif ctx.cfg != cfg:
        # steps read ctx.cfg, init reads cfg — a silent mismatch would run
        # the wrong config; rebind with ctx.replace(cfg=...) to share the
        # traced graph arrays across config variants (e.g. a Psi sweep)
        raise ValueError(
            "ctx.cfg differs from cfg; pass ctx.replace(cfg=cfg) to reuse "
            "a context across config variants")
    elif workload is not None and ctx.task != workload:
        # equality, not identity: equal Task instances (e.g. two
        # with_optimizer() copies) are the same static jit key
        raise ValueError(
            "ctx.task differs from the task/loss_fn argument; pass "
            "ctx.replace(task=...) to rebind the workload")
    metric_name = "accuracy"
    if eval_fn is None and is_task(ctx.task) and eval_data is not None:
        eval_fn = ctx.task.eval_fn
    if is_task(ctx.task) and eval_fn is ctx.task.eval_fn:
        metric_name = ctx.task.metric_name
    if state is None:
        if key is None:
            raise ValueError("key is required when no state is given")
        state = algo.init(key, cfg, params0, task=ctx.task)
    if eval_fn is not None and eval_data is None:
        raise ValueError("eval_fn requires eval_data=(ex, ey)")

    state, raw = _run(algo, ctx, state, eval_data, int(num_steps),
                      int(eval_every), eval_fn, metric_name)

    if raw is None:
        return state, SimTrace(np.zeros((0,), np.int32), {})
    step = np.asarray(raw["step"])
    metrics = {k: np.asarray(v) for k, v in raw.items() if k != "step"}
    return state, SimTrace(step, metrics)


def resolve_workload(cfg, task, task_key, loss_fn, params0, data, eval_data,
                     *, need_params: bool, need_data: bool):
    """Shared task plumbing for `simulate` / `simulate_sweep`.

    Resolves registry names, promotes a `Task` passed in the legacy
    loss position, rejects conflicting spellings, and builds only the
    *missing, actually-consumed* pieces from the task's builders
    (`need_params` is False on a warm restart with a prebuilt ctx;
    `need_data` is False whenever a prebuilt ctx supplies the shards —
    regenerating a dataset that the scan never reads would also inject
    an eval set drawn from different mixture anchors).

    Returns ``(task, workload, params0, data, eval_data)`` where
    `workload` is what the context carries (the task, or the bare loss
    callable on the legacy path).
    """
    from repro.tasks import get_task, is_task

    if isinstance(task, str):
        task = get_task(task)
    if task is None and is_task(loss_fn):
        task = loss_fn  # Task passed in the legacy loss position
    if task is not None:
        if loss_fn is not None and loss_fn is not task:
            raise ValueError("pass the workload as either task= or "
                             "loss_fn=, not both")
        need_params = need_params and params0 is None
        need_data = need_data and data is None
        if need_params or need_data:
            tk = task_key if task_key is not None else jax.random.PRNGKey(0)
            kp, kd = jax.random.split(tk)  # Task.setup's key derivation
            if need_params:
                params0 = task.init_params(kp)
            if need_data:
                data, ev = task.make_data(kd, cfg.num_clients)
                if eval_data is None:
                    eval_data = ev
    elif task_key is not None:
        raise ValueError("task_key given without task=")
    workload = task if task is not None else loss_fn
    return task, workload, params0, data, eval_data


def steps_for_budget(algo: Union[str, Algorithm], cfg, budget_grads: float,
                     task=None) -> int:
    """Steps matching a per-client compute budget for any algorithm.

    Without `task` (legacy), `budget_grads` counts expected local
    gradient *events* per client and every event is priced uniformly —
    the compute-matched budget of the paper's Fig. 3 (DRACO fires
    1-exp(-lambda*w) grads/client/window, sync baselines 1/round, async
    baselines p_active/round). That uniform pricing is only correct
    when every method runs the same workload.

    With `task` (a `repro.tasks.Task` or registry name), each event is
    priced at `task.grad_cost` (relative FLOPs per local gradient
    event), so `budget_grads` is a *FLOP* budget in the same units and
    budget-matched runs equalize expected FLOPs — across algorithms
    *and* across tasks of different model sizes:

        steps = budget / (grads_per_step(cfg) * grad_cost)

    tests/test_tasks.py pins the equalization.
    """
    if isinstance(algo, str):
        algo = get_algorithm(algo)
    cost = 1.0
    if task is not None:
        from repro.tasks import get_task

        t = get_task(task) if isinstance(task, str) else task
        cost = t.grad_cost
    rate = algo.grads_per_step(cfg) * cost
    return max(1, int(round(budget_grads / max(rate, 1e-12))))
