"""Unified compiled simulation driver for any registered `Algorithm`.

`simulate(algo, cfg, params0, loss_fn, data, num_steps, ...)` runs the
whole protocol inside **one** `jax.lax.scan` with *in-jit* metric
sampling: every `eval_every` steps a `lax.cond` computes the metric dict
(mean client accuracy on a held-out set, consensus distance) directly on
device, so there are no per-segment host round-trips and no re-dispatch
— one compile per (algorithm, config, loss), then a single device call
regardless of how often you sample.

`steps_for_budget` converts a compute budget (expected local-SGD
invocations per client) into a step count for any algorithm, expressing
the paper's compute-matched comparisons in one place.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.algorithm import Algorithm, get_algorithm
from repro.api.context import SimContext, make_context


class SimTrace(NamedTuple):
    """In-jit metric trace, compressed to the sampled steps (host side).

    `step[k]` is the 1-indexed step count after which `metrics[...][k]`
    was measured; empty arrays when `eval_every == 0`.
    """

    step: np.ndarray  # (num_evals,) int
    metrics: Dict[str, np.ndarray]  # each (num_evals,) float


def consensus_distance(params) -> jax.Array:
    """RMS distance of per-client params to the virtual global model:
    sqrt(mean_i ||x_i - x_bar||^2), summed over all leaves (Sec. 2.1)."""
    sq = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(params):
        x = leaf.astype(jnp.float32)
        xbar = x.mean(axis=0, keepdims=True)
        sq = sq + ((x - xbar) ** 2).sum() / x.shape[0]
    return jnp.sqrt(sq)


def _metrics(algo, state, eval_fn, eval_data):
    p = algo.eval_params(state)
    out = {"consensus": consensus_distance(p)}
    if eval_fn is not None:
        ex, ey = eval_data
        out["accuracy"] = jax.vmap(lambda pi: eval_fn(pi, ex, ey))(p).mean().astype(jnp.float32)
    return out


@partial(jax.jit, static_argnames=("algo", "num_steps", "eval_every", "eval_fn"))
def _run(algo, ctx, state, eval_data, num_steps: int, eval_every: int, eval_fn):
    """One fused scan over `num_steps` protocol steps + in-jit eval."""
    if eval_every > 0:
        zeros = {"consensus": jnp.zeros((), jnp.float32)}
        if eval_fn is not None:
            zeros["accuracy"] = jnp.zeros((), jnp.float32)

        def body(s, i):
            s = algo.step(s, ctx)
            do = jnp.mod(i + 1, eval_every) == 0
            m = jax.lax.cond(
                do,
                lambda st: _metrics(algo, st, eval_fn, eval_data),
                lambda st: zeros,
                s,
            )
            return s, dict(m, step=(i + 1).astype(jnp.int32), mask=do)

    else:

        def body(s, i):
            return algo.step(s, ctx), None

    state, trace = jax.lax.scan(body, state, jnp.arange(num_steps, dtype=jnp.int32))
    return state, trace


def simulate(
    algo: Union[str, Algorithm],
    cfg,
    params0,
    loss_fn: Optional[Callable] = None,
    data: Any = None,
    num_steps: int = 1,
    *,
    key=None,
    eval_every: int = 0,
    eval_fn: Optional[Callable] = None,
    eval_data: Any = None,
    ctx: Optional[SimContext] = None,
    state: Any = None,
    graph_key=None,
):
    """Run `num_steps` of any registered algorithm in one compiled call.

    Args:
      algo: registry name (e.g. "draco", "sync-push") or an `Algorithm`.
      cfg: `DracoConfig`-style frozen config (static: hashable).
      params0: single-client param pytree (ignored when `state` given).
      loss_fn: `loss(params_i, x, y)` used by local SGD (static).
      data: federated train shards `(xs, ys)` with leading client axis.
      num_steps: protocol steps (DRACO windows / baseline rounds).
      key: PRNGKey for state init (required unless `state` is given).
      eval_every: sample metrics every k steps inside the scan
        (`lax.cond`); 0 disables in-jit eval entirely.
      eval_fn: `metric(params_i, ex, ey) -> scalar` (e.g. accuracy);
        vmapped over clients and averaged. Requires `eval_data`.
      eval_data: held-out `(ex, ey)` for `eval_fn`.
      ctx: prebuilt `SimContext` to share graph/channel construction
        across runs; built from (cfg, loss_fn, data) when omitted.
      state: resume from an existing algorithm state.
      graph_key: PRNGKey for random topologies (passed to `make_context`).

    Returns:
      (final_state, SimTrace) — the trace is compressed host-side to the
      sampled steps.
    """
    if isinstance(algo, str):
        algo = get_algorithm(algo)
    if ctx is None:
        ctx = make_context(cfg, loss_fn, data, graph_key=graph_key)
    elif ctx.cfg != cfg:
        # steps read ctx.cfg, init reads cfg — a silent mismatch would run
        # the wrong config; rebind with ctx.replace(cfg=...) to share the
        # traced graph arrays across config variants (e.g. a Psi sweep)
        raise ValueError(
            "ctx.cfg differs from cfg; pass ctx.replace(cfg=cfg) to reuse "
            "a context across config variants")
    if state is None:
        if key is None:
            raise ValueError("key is required when no state is given")
        state = algo.init(key, cfg, params0)
    if eval_fn is not None and eval_data is None:
        raise ValueError("eval_fn requires eval_data=(ex, ey)")

    state, raw = _run(algo, ctx, state, eval_data, int(num_steps),
                      int(eval_every), eval_fn)

    if raw is None:
        return state, SimTrace(np.zeros((0,), np.int64), {})
    mask = np.asarray(raw["mask"])
    step = np.asarray(raw["step"])[mask]
    metrics = {
        k: np.asarray(v)[mask]
        for k, v in raw.items()
        if k not in ("mask", "step")
    }
    return state, SimTrace(step, metrics)


def steps_for_budget(algo: Union[str, Algorithm], cfg,
                     budget_grads: float) -> int:
    """Steps giving ~`budget_grads` expected local-SGD invocations per
    client — the compute-matched budget of the paper's Fig. 3 (DRACO
    fires 1-exp(-lambda*w) grads/client/window, sync baselines 1/round,
    async baselines p_active/round)."""
    if isinstance(algo, str):
        algo = get_algorithm(algo)
    rate = algo.grads_per_step(cfg)
    return max(1, int(round(budget_grads / max(rate, 1e-12))))
