"""Unreliable wireless channel model (paper Sec. 5).

Transmission time from i to j:
    Gamma_ij = msg_bytes*8 / (W log2(1 + SINR_ij)) + dist(i,j)/c
    SINR_ij  = P h_ij d_ij^-a / (sum_{n in interferers(j)} P h_nj d_nj^-a + z^2)
with Rayleigh fading h ~ exp(1) resampled per transmission. A message is
lost iff Gamma_ij > Gamma_max. Nodes interfere when within 0.1*R.

Defaults follow the paper: R=500 m, P=30 dBm, alpha=4, W=10 MHz,
N0=-174 dBm/Hz.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

LIGHTSPEED = 3.0e8


@dataclass(frozen=True)
class ChannelConfig:
    radius: float = 500.0  # m
    tx_power_dbm: float = 30.0
    path_loss_exp: float = 4.0
    bandwidth_hz: float = 10e6
    noise_dbm_hz: float = -174.0
    interference_radius_frac: float = 0.1
    message_bytes: int = 596_776
    gamma_max: float = 10.0  # s, delay deadline
    enabled: bool = True

    @property
    def tx_power_w(self) -> float:
        return 10 ** (self.tx_power_dbm / 10) / 1e3

    @property
    def noise_w(self) -> float:
        return 10 ** (self.noise_dbm_hz / 10) / 1e3 * self.bandwidth_hz


def place_nodes(key, n: int, cfg: ChannelConfig) -> jax.Array:
    """Uniform positions in a disk of radius R. (n, 2)."""
    k1, k2 = jax.random.split(key)
    r = cfg.radius * jnp.sqrt(jax.random.uniform(k1, (n,)))
    th = 2 * jnp.pi * jax.random.uniform(k2, (n,))
    return jnp.stack([r * jnp.cos(th), r * jnp.sin(th)], axis=-1)


def pairwise_dist(pos) -> jax.Array:
    d = jnp.linalg.norm(pos[:, None, :] - pos[None, :, :], axis=-1)
    return jnp.maximum(d, 1.0)  # clamp to 1 m (avoid singular path loss)


def interference(dist, p_rx, tx_mask, cfg: ChannelConfig) -> jax.Array:
    """Aggregate interference seen on each link i -> j: total received
    power at j from concurrently transmitting nodes within the
    interference radius, minus i's own signal when i is itself close.

    The self-subtraction removes one term of the sum it was part of, so
    the result is non-negative up to f32 rounding; the clamp absorbs
    that rounding (tests pin both facts). Returns (n, n), [i, j] =
    interference on the i -> j link.
    """
    close = dist <= cfg.interference_radius_frac * cfg.radius  # [n, j]
    contrib = jnp.where(close & tx_mask[:, None], p_rx, 0.0)  # [n, j]
    interf = contrib.sum(axis=0)[None, :] - contrib
    return jnp.maximum(interf, 0.0)


def transmission_delays(key, pos, tx_mask, cfg: ChannelConfig):
    """Sample per-link delay Gamma (n, n) [seconds] and success mask.

    tx_mask (n,) marks concurrently transmitting nodes (they interfere).
    Returns (gamma (n,n), success (n,n) bool) where entry [i, j] refers to
    the link i -> j. success = Gamma <= gamma_max and i actually transmits.
    """
    n = pos.shape[0]
    dist = pairwise_dist(pos)  # (n, n) dist[i, j]
    h = jax.random.exponential(key, (n, n))  # fading per link
    p_rx = cfg.tx_power_w * h * dist ** (-cfg.path_loss_exp)  # [i,j]: power of i at j

    sinr = p_rx / (interference(dist, p_rx, tx_mask, cfg) + cfg.noise_w)
    rate = cfg.bandwidth_hz * jnp.log2(1.0 + sinr)
    gamma = (cfg.message_bytes * 8) / jnp.maximum(rate, 1e-9) + dist / LIGHTSPEED
    success = (gamma <= cfg.gamma_max) & tx_mask[:, None]
    return gamma, success


def geometric_adjacency(pos, max_range: float) -> jax.Array:
    """Boolean links from channel geometry: i -> j iff dist(i, j) <=
    max_range, zero diagonal. The random-waypoint scenario re-derives
    the gossip graph from this every mobility epoch."""
    n = pos.shape[0]
    return (pairwise_dist(pos) <= max_range) & ~jnp.eye(n, dtype=bool)


def waypoint_step(pos, waypoints, speed: float):
    """One random-waypoint hop: move each node `speed` meters toward its
    target, snapping onto targets within reach. Returns (new_pos (n, 2),
    arrived (n,) bool); the caller resamples targets for arrived nodes.
    """
    d = waypoints - pos
    dist = jnp.linalg.norm(d, axis=-1, keepdims=True)
    arrived = dist[..., 0] <= speed
    step = d / jnp.maximum(dist, 1e-9) * speed
    return jnp.where(arrived[:, None], waypoints, pos + step), arrived
