"""Unreliable wireless channel model (paper Sec. 5).

Transmission time from i to j:
    Gamma_ij = msg_bytes*8 / (W log2(1 + SINR_ij)) + dist(i,j)/c
    SINR_ij  = P h_ij d_ij^-a / (sum_{n in interferers(j)} P h_nj d_nj^-a + z^2)
with Rayleigh fading h ~ exp(1) resampled per transmission. A message is
lost iff Gamma_ij > Gamma_max. Nodes interfere when within 0.1*R.

Defaults follow the paper: R=500 m, P=30 dBm, alpha=4, W=10 MHz,
N0=-174 dBm/Hz.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

LIGHTSPEED = 3.0e8


@dataclass(frozen=True)
class ChannelConfig:
    radius: float = 500.0  # m
    tx_power_dbm: float = 30.0
    path_loss_exp: float = 4.0
    bandwidth_hz: float = 10e6
    noise_dbm_hz: float = -174.0
    interference_radius_frac: float = 0.1
    message_bytes: int = 596_776
    gamma_max: float = 10.0  # s, delay deadline
    enabled: bool = True

    @property
    def tx_power_w(self) -> float:
        return 10 ** (self.tx_power_dbm / 10) / 1e3

    @property
    def noise_w(self) -> float:
        return 10 ** (self.noise_dbm_hz / 10) / 1e3 * self.bandwidth_hz


def place_nodes(key, n: int, cfg: ChannelConfig) -> jax.Array:
    """Uniform positions in a disk of radius R. (n, 2)."""
    k1, k2 = jax.random.split(key)
    r = cfg.radius * jnp.sqrt(jax.random.uniform(k1, (n,)))
    th = 2 * jnp.pi * jax.random.uniform(k2, (n,))
    return jnp.stack([r * jnp.cos(th), r * jnp.sin(th)], axis=-1)


def pairwise_dist(pos) -> jax.Array:
    d = jnp.linalg.norm(pos[:, None, :] - pos[None, :, :], axis=-1)
    return jnp.maximum(d, 1.0)  # clamp to 1 m (avoid singular path loss)


def transmission_delays(key, pos, tx_mask, cfg: ChannelConfig):
    """Sample per-link delay Gamma (n, n) [seconds] and success mask.

    tx_mask (n,) marks concurrently transmitting nodes (they interfere).
    Returns (gamma (n,n), success (n,n) bool) where entry [i, j] refers to
    the link i -> j. success = Gamma <= gamma_max and i actually transmits.
    """
    n = pos.shape[0]
    dist = pairwise_dist(pos)  # (n, n) dist[i, j]
    h = jax.random.exponential(key, (n, n))  # fading per link
    p_rx = cfg.tx_power_w * h * dist ** (-cfg.path_loss_exp)  # [i,j]: power of i at j

    # interferers of receiver j: transmitting nodes n != i within 0.1R of j
    close = dist <= cfg.interference_radius_frac * cfg.radius  # [n, j]
    interf_all = jnp.einsum("nj,n->j", (close & tx_mask[:, None]).astype(jnp.float32) * p_rx.astype(jnp.float32), jnp.ones((n,)))
    # subtract own signal when i itself is close to j
    interf = interf_all[None, :] - jnp.where(close & tx_mask[:, None], p_rx, 0.0)
    sinr = p_rx / (jnp.maximum(interf, 0.0) + cfg.noise_w)
    rate = cfg.bandwidth_hz * jnp.log2(1.0 + sinr)
    gamma = (cfg.message_bytes * 8) / jnp.maximum(rate, 1e-9) + dist / LIGHTSPEED
    success = (gamma <= cfg.gamma_max) & tx_mask[:, None]
    return gamma, success
