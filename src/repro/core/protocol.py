"""DRACO: the decentralized asynchronous protocol (Algorithm 1/2).

Compiled simulation over *superposition windows* (the paper's own
discretization device, Sec. 2.2): one `draco_window` = one jit step.
Within a window each client independently (Poisson thinning):

  - fires a *gradient event*: B local SGD batches -> accumulates a pending
    update Delta (backups accumulate between transmissions, Lemma A.1);
  - fires a *transmission event*: broadcasts its pending Delta through the
    (optional) unreliable wireless channel; per-link delays are quantized
    to windows and routed through a ring delay-buffer;
  - receives: messages arriving this window are aggregated with the
    row-stochastic weights, x_j += sum_i q[i,j] Delta_i, subject to the
    Psi cap (Definition 1);
  - periodic unification: every P windows a rotating hub broadcasts its
    reference model and every client adopts it (x_j <- x_hub).

Computation and communication schedules are fully decoupled: the grad and
tx processes are independent, and nothing ever waits.

Fused gossip engine (PR 2)
--------------------------
The communication state lives on the *flat parameter plane*
(`repro.core.flat`): `DracoState.buffer` is one contiguous
``(D, N, Dflat)`` f32 ring of **raw broadcast payloads**, and the
delay-bucketed mixing is deferred from enqueue to drain:

  - enqueue (send window w): write the sender's flat pending matrix into
    ring slot ``w % D`` together with that window's effective weights
    ``Q ⊙ accept`` and per-link delay matrix — O(N·Dflat) instead of the
    seed's D-1 full-pytree masked einsums per window;
  - drain (window w): everything arriving now is
    ``sum_j (Q_j ⊙ [delay_j == age_j])^T @ buffer[slot_j]`` over the D-1
    stored broadcasts — one fused pass (`gossip_ops.gossip_drain`):
    a single Pallas grid on TPU, an unrolled GEMM loop with
    empty-bucket skipping elsewhere.

The accumulation order (oldest broadcast first) matches the seed ring
buffer exactly, so the fused engine is bit-for-bit equal to the legacy
path at f32 — enforced by tests/test_protocol_parity.py against the
`*_legacy` reference implementations kept at the bottom of this module.

Task layer (PR 5)
-----------------
The workload slot of every step function accepts either a bare
``loss(params, x, y)`` callable — the legacy plain-SGD path, compiled
graph unchanged — or a `repro.tasks.Task` bundling model init/apply, a
federated dataset, an eval metric, and a **local optimizer** from
`repro.optim` whose per-client state rides a flat ``(N, Dopt)`` plane
(`DracoState.opt_state`) next to the ``(N, Dflat)`` payloads. The
optimizer plane is client-local: it is never gossiped, and hub
unification overwrites params only. Dispatch lives in `local_step`;
the default ``linear-softmax`` + ``sgd(constant)`` task is bit-for-bit
the bare-loss path (tests/test_tasks.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

import jax.flatten_util

from repro.core import channel as channel_lib
from repro.core import flat as flat_lib
from repro.core.channel import ChannelConfig
from repro.core.events import sample_event_masks
from repro.core.topology import adjacency, row_stochastic
from repro.kernels.gossip import ops as gossip_ops
from repro.optim.optimizers import apply_updates


@dataclass(frozen=True)
class DracoConfig:
    num_clients: int = 25
    lr: float = 0.05  # gamma
    local_batches: int = 1  # B
    batch_size: int = 64
    window: float = 1.0  # superposition window length (s)
    lambda_grad: float = 0.1  # Assumption 1 rate (paper default)
    lambda_tx: float = 0.1
    unify_period: int = 50  # P, in windows (0 = no unification)
    psi: int = 0  # max accepted msgs / client / period (0 = unbounded)
    topology: str = "cycle"
    max_delay_windows: int = 4  # ring buffer depth D (>= 2)
    apply_self_update: bool = False  # paper: senders do NOT apply own Delta
    channel: Optional[ChannelConfig] = None

    def __post_init__(self):
        if self.num_clients <= 0:
            raise ValueError(
                f"num_clients must be positive, got {self.num_clients}")
        if self.window <= 0:
            raise ValueError(
                f"window must be positive, got {self.window}")
        if self.max_delay_windows < 2:
            # the drain walks ages 1..D-1; D < 2 leaves no in-flight slot
            # and the ring silently degenerates to "nothing ever arrives"
            raise ValueError(
                "max_delay_windows must be >= 2 (depth-D ring holds D-1 "
                f"in-flight windows), got {self.max_delay_windows}")
        if self.psi < 0:
            raise ValueError(
                f"psi must be >= 0 (0 = unbounded), got {self.psi}")
        if self.unify_period < 0:
            raise ValueError(
                f"unify_period must be >= 0 (0 = never), got {self.unify_period}")

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


class Overrides(NamedTuple):
    """Traced per-run overrides of sweepable `DracoConfig` fields.

    The sweep engine (`repro.api.sweep`) re-binds these inside one
    compiled call, so an lr/Psi/lambda grid shares a single trace instead
    of recompiling per config. `None` fields fall back to the static
    config value — an all-None `Overrides` is bit-for-bit the plain
    config path. `psi` follows the config convention: values <= 0 mean
    unbounded reception.
    """

    lr: Optional[jax.Array] = None
    lambda_grad: Optional[jax.Array] = None
    lambda_tx: Optional[jax.Array] = None
    psi: Optional[jax.Array] = None


class DracoState(NamedTuple):
    params: Any  # pytree, leaves (N, ...)
    pending: jax.Array  # (N, Dflat) f32 — accumulated untransmitted updates
    buffer: jax.Array  # (D, N, Dflat) f32 — raw broadcast payload ring
    w_ring: jax.Array  # (D, N, N) f32 — per-slot effective weights Q ⊙ accept
    delay_ring: jax.Array  # (D, N, N) int32 — per-slot per-link delays
    accept_count: jax.Array  # (N,) messages accepted this period
    total_accept: jax.Array  # (N,) messages accepted over the whole run
    window_idx: jax.Array  # scalar int32
    key: jax.Array
    positions: jax.Array  # (N, 2) node coordinates (channel model)
    opt_state: jax.Array = ()  # (N, Dopt) f32 — flat local optimizer plane


def _opt_plane(task, params0, n) -> jax.Array:
    """Zero-initialized (N, Dopt) optimizer plane for `task` (Dopt=0 for
    bare-loss/plain-SGD workloads — an empty column block)."""
    from repro.tasks.base import opt_width

    return jnp.zeros((n, opt_width(task, params0)), jnp.float32)


def init_state(key, cfg: DracoConfig, params0, task=None) -> DracoState:
    """params0: single-client param pytree -> replicated across N clients.

    `task` (a `repro.tasks.Task`), when given, sizes the flat local
    optimizer plane `opt_state` from its update rule (momentum -> Dflat,
    adamw -> 2*Dflat + a per-client step counter); None or a bare loss
    callable means plain SGD and
    an empty (N, 0) plane — the pre-task layout, bit-for-bit."""
    n, d = cfg.num_clients, cfg.max_delay_windows
    kp, ks = jax.random.split(key)
    params = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape).copy(), params0
    )
    spec = flat_lib.spec_of(params)
    pos = channel_lib.place_nodes(kp, n, cfg.channel or ChannelConfig())
    return DracoState(
        params=params,
        pending=jnp.zeros((n, spec.dim), jnp.float32),
        buffer=jnp.zeros((d, n, spec.dim), jnp.float32),
        w_ring=jnp.zeros((d, n, n), jnp.float32),
        delay_ring=jnp.zeros((d, n, n), jnp.int32),
        accept_count=jnp.zeros((n,), jnp.int32),
        total_accept=jnp.zeros((n,), jnp.int32),
        window_idx=jnp.zeros((), jnp.int32),
        key=ks,
        positions=pos,
        opt_state=_opt_plane(task, params0, n),
    )


def local_updates(key, params, grad_mask, cfg, loss_fn, data, *, lr=None):
    """Per-client B-batch local SGD; returns Delta pytree (N, ...).

    `lr`, when given, is a traced learning-rate override (config sweeps);
    None keeps the static `cfg.lr` bit-for-bit."""
    xs, ys = data
    n = cfg.num_clients
    lr = cfg.lr if lr is None else lr

    def one_client(p_i, key_i, x_i, y_i):
        def body(p, k):
            idx = jax.random.randint(k, (cfg.batch_size,), 0, x_i.shape[0])
            g = jax.grad(loss_fn)(p, x_i[idx], y_i[idx])
            return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g), None

        keys = jax.random.split(key_i, cfg.local_batches)
        y_b, _ = jax.lax.scan(body, p_i, keys)
        return jax.tree_util.tree_map(lambda yb, p: yb - p, y_b, p_i)

    keys = jax.random.split(key, n)
    delta = jax.vmap(one_client)(params, keys, xs, ys)
    gm = grad_mask.astype(jnp.float32)
    return jax.tree_util.tree_map(
        lambda dl: dl * gm.reshape((n,) + (1,) * (dl.ndim - 1)), delta
    )


def task_local_updates(key, params, grad_mask, cfg, task, data, opt_state,
                       step, *, lr=None):
    """Per-client B-batch local updates through the task's optimizer.

    The task-layer generalization of `local_updates`: each local batch
    computes a gradient and feeds it to the task's `repro.optim` update
    rule instead of the hard-coded ``p - lr*g``. The per-client optimizer
    state lives on the flat plane — `opt_state` is the ``(N, Dopt)`` f32
    matrix; inside the per-client body it is unraveled into the
    optimizer's pytree (exact reshape/concat round-trip) and raveled
    back out. Clients whose `grad_mask` is off fired no gradient event:
    their delta is zeroed (as before) **and** their optimizer state is
    left untouched.

    With the default plain SGD + constant schedule this is bit-for-bit
    `local_updates` (``p + g*(-lr)`` and ``p - lr*g`` are the same f32
    values; tests/test_tasks.py pins the equality through full runs).

    `step` (traced int32) feeds the lr schedule and AdamW bias
    correction — the protocol's window/round counter, shared by the B
    in-window batches. `lr`, when given, is a traced override re-seeding
    the schedule (config sweeps); None keeps the static `cfg.lr`.
    Returns ``(delta pytree (N, ...), new opt_state (N, Dopt))``.
    """
    xs, ys = data
    n = cfg.num_clients
    lr = cfg.lr if lr is None else lr
    opt = task.make_optimizer(lr)
    loss_fn = task.loss_fn

    def one_client(p_i, key_i, x_i, y_i, o_i):
        _, unravel = jax.flatten_util.ravel_pytree(opt.init(p_i))
        o0 = unravel(o_i)

        def body(carry, k):
            p, o = carry
            idx = jax.random.randint(k, (cfg.batch_size,), 0, x_i.shape[0])
            g = jax.grad(loss_fn)(p, x_i[idx], y_i[idx])
            upd, o = opt.update(g, o, p, step)
            return (apply_updates(p, upd), o), None

        keys = jax.random.split(key_i, cfg.local_batches)
        (p_b, o), _ = jax.lax.scan(body, (p_i, o0), keys)
        delta = jax.tree_util.tree_map(lambda pb, p: pb - p, p_b, p_i)
        return delta, jax.flatten_util.ravel_pytree(o)[0]

    keys = jax.random.split(key, n)
    delta, opt_new = jax.vmap(one_client)(params, keys, xs, ys, opt_state)
    gm = grad_mask.astype(jnp.float32)
    delta = jax.tree_util.tree_map(
        lambda dl: dl * gm.reshape((n,) + (1,) * (dl.ndim - 1)), delta
    )
    opt_new = jnp.where(grad_mask[:, None], opt_new, opt_state)
    return delta, opt_new


def local_step(key, params, grad_mask, cfg, task, data, opt_state, step, *,
               lr=None):
    """Dispatch local updates by workload representation.

    A bare loss callable (or None task) runs the seed `local_updates`
    graph unchanged — the exact pre-task compiled path, the `opt_state`
    (N, Dopt) optimizer plane threaded through untouched. A
    `repro.tasks.Task` routes through `task_local_updates` (pluggable
    optimizer, state on the flat plane).
    """
    if task is None or not hasattr(task, "loss_fn"):
        return (local_updates(key, params, grad_mask, cfg, task, data, lr=lr),
                opt_state)
    return task_local_updates(key, params, grad_mask, cfg, task, data,
                              opt_state, step, lr=lr)


def _psi_accept(key, success, accept_count, psi):
    """Per-(sender, receiver) acceptance under the Psi cap.

    Random sender priority; receiver j accepts while its period count +
    rank < psi. Returns (accept mask (N,N), new accept_count).

    `psi` may be a static int (the config path) or a traced int scalar
    (config sweeps). A traced psi <= 0 encodes "unbounded" via a cap no
    run can reach, which reproduces the static unbounded path bit-for-bit
    (the rank test degenerates to `arrivals > 0`)."""
    n = success.shape[0]
    arrivals = success.astype(jnp.int32)
    if isinstance(psi, (int, np.integer)):
        if psi <= 0:
            return success, accept_count + arrivals.sum(axis=0)
    else:
        psi = jnp.where(psi <= 0, jnp.iinfo(jnp.int32).max // 2,
                        psi.astype(jnp.int32))
    perm = jax.random.permutation(key, n)  # sender priority order
    inv = jnp.argsort(perm)
    s_perm = arrivals[perm]  # reorder senders
    rank = jnp.cumsum(s_perm, axis=0) - s_perm  # msgs ahead of me (per recv)
    ok_perm = (rank + accept_count[None, :] < psi) & (s_perm > 0)
    ok = ok_perm[inv]
    new_count = accept_count + ok.sum(axis=0).astype(jnp.int32)
    return ok & success, new_count


def quantize_delays(gamma, window: float, max_delay_windows: int):
    """Per-link delay in superposition windows + deliverability mask.

    ``delay_w = clip(ceil(gamma / window), 1, D-1)`` routes each link
    through the depth-D ring; a link whose true delay spans >= D windows
    cannot be delivered from the ring at its actual age, so it is
    **dropped** (channel-outage semantics) rather than silently delivered
    early at age D-1 — the exact boundary ``gamma = (D-1) * window`` is
    still deliverable. Returns (delay_w (N,N) int32, deliverable (N,N)
    bool)."""
    raw = jnp.ceil(gamma / window).astype(jnp.int32)  # >= 1 typically
    deliverable = raw <= max_delay_windows - 1
    return jnp.clip(raw, 1, max_delay_windows - 1), deliverable


def _tx_and_accept(state, cfg, q, adj, k_tx, k_chan, k_psi, positions=None,
                   tx_rate=None, overrides=None):
    """Transmission events + channel + Psi cap (shared by both engines).

    `positions`/`tx_rate`, when given (scenario schedules), override the
    state-carried node coordinates and scale the per-client Poisson tx
    rate; None means the frozen-path behavior, bit-for-bit. `overrides`
    (an `Overrides`) re-binds lambda_tx/psi with traced values for the
    sweep engine.

    Returns (tx_mask (N,), w_eff (N,N), delay_w (N,N) int32,
    accept_count, total_accept)."""
    n, D = cfg.num_clients, cfg.max_delay_windows
    ov = overrides or Overrides()
    lam_tx = cfg.lambda_tx if ov.lambda_tx is None else ov.lambda_tx
    if tx_rate is not None:
        lam_tx = lam_tx * tx_rate
    tx_mask = sample_event_masks(k_tx, lam_tx, cfg.window, n)
    if cfg.channel is not None and cfg.channel.enabled:
        pos = state.positions if positions is None else positions
        gamma, success = channel_lib.transmission_delays(
            k_chan, pos, tx_mask, cfg.channel
        )
        delay_w, deliverable = quantize_delays(gamma, cfg.window, D)
        success = success & deliverable & adj
    else:
        success = adj & tx_mask[:, None]
        delay_w = jnp.ones((n, n), jnp.int32)

    psi = cfg.psi if ov.psi is None else ov.psi
    accept, accept_count = _psi_accept(k_psi, success, state.accept_count, psi)
    # cumulative counter survives the periodic accept_count reset
    total_accept = state.total_accept + (accept_count - state.accept_count)
    w_eff = q * accept.astype(q.dtype)  # (sender, receiver)
    return tx_mask, w_eff, delay_w, accept_count, total_accept


def _unify(params, accept_count, widx, cfg, n):
    """Periodic unification: rotating hub broadcast + accept-count reset."""

    def unify(args):
        p, cnt = args
        hub = jnp.mod((widx // jnp.maximum(cfg.unify_period, 1)), n)
        p = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[hub][None], x.shape), p
        )
        return p, jnp.zeros_like(cnt)

    do_unify = jnp.mod(widx + 1, cfg.unify_period) == 0
    return jax.lax.cond(do_unify, unify, lambda a: a, (params, accept_count))


def draco_window(state: DracoState, cfg: DracoConfig, q, adj, task, data,
                 spec=None, *, positions=None, compute_rate=None,
                 tx_rate=None, overrides=None, damping=None):
    """One superposition window on the fused gossip engine.

    Bit-for-bit equal to `draco_window_legacy` at f32 (the parity suite
    enforces it); see the module docstring for the enqueue/drain design.
    `q` (N, N) is the row-stochastic mixing matrix, `adj` its boolean
    adjacency.
    `task` is the workload: a `repro.tasks.Task` (model + data + local
    optimizer, state on the flat plane) or — the legacy shim — a bare
    ``loss(params, x, y)`` callable, which runs the seed plain-SGD graph
    unchanged. `spec` is the flat-plane layout (`FlatSpec`); pass the
    one stored on `SimContext` to share it across steps, or omit it to
    derive it from `state.params` at trace time.

    The keyword-only trio carries a scenario schedule's step-t snapshot
    (`repro.scenarios`): `positions` (N, 2) overrides the state-carried
    node coordinates for this window's channel draws (and is written
    back to the state, so mobility is visible downstream);
    `compute_rate`/`tx_rate` (N,) scale the per-client Poisson
    grad/transmission rates (straggler profiles modulate the decoupled
    computation schedule without touching the comms schedule). All
    default to None == the frozen-graph path, bit-for-bit.

    `overrides` (an `Overrides`) re-binds lr/lambda/psi with *traced*
    scalars — the sweep engine's config axis; None fields keep the
    static config values bit-for-bit.

    `damping` is an optional age-indexed ``(D,)`` f32 vector scaling the
    drain's per-bucket weights: the bucket whose messages are ``j``
    windows old is multiplied by ``damping[j]`` before the fused drain —
    the staleness-adaptive mixing hook (`repro.events.staleness`
    builds the FedAsync constant/hinge/poly vectors). None keeps the
    undamped drain bit-for-bit.
    """
    n, D = cfg.num_clients, cfg.max_delay_windows
    ov = overrides or Overrides()
    keys = jax.random.split(state.key, 8)
    k_next, k_grad, k_gsel, k_tx, k_chan, k_psi, k_hub, _ = keys
    widx = state.window_idx
    if spec is None:
        spec = flat_lib.spec_of(state.params)

    # --- 1. deliveries: fused delay-bucketed drain on the flat plane ------
    # Stored broadcast of age j (sent in window widx-j) arrives now iff its
    # per-link delay equals j.  Stack oldest-first so the f32 accumulation
    # order matches the seed ring buffer exactly.
    ages = jnp.arange(D - 1, 0, -1, dtype=jnp.int32)
    slots = jnp.mod(widx - ages, D)
    w_stack = state.w_ring[slots] * (
        state.delay_ring[slots] == ages[:, None, None]
    ).astype(state.w_ring.dtype)
    if damping is not None:
        w_stack = w_stack * damping[ages][:, None, None]
    arrivals_flat = gossip_ops.gossip_drain(w_stack, state.buffer, slots)
    arrivals = flat_lib.unravel_clients(arrivals_flat, spec)
    params = jax.tree_util.tree_map(
        lambda p, a: p + a.astype(p.dtype), state.params, arrivals
    )

    # --- 2. gradient events ------------------------------------------------
    lam_g = cfg.lambda_grad if ov.lambda_grad is None else ov.lambda_grad
    if compute_rate is not None:
        lam_g = lam_g * compute_rate
    grad_mask = sample_event_masks(k_grad, lam_g, cfg.window, n)
    delta, opt_state = local_step(k_gsel, params, grad_mask, cfg, task, data,
                                  state.opt_state, widx, lr=ov.lr)
    pending = state.pending + flat_lib.ravel_clients(delta)
    if cfg.apply_self_update:
        params = jax.tree_util.tree_map(
            lambda p, dl: p + dl.astype(p.dtype), params, delta
        )

    # --- 3. transmission events + channel ----------------------------------
    tx_mask, w_eff, delay_w, accept_count, total_accept = _tx_and_accept(
        state, cfg, q, adj, k_tx, k_chan, k_psi, positions=positions,
        tx_rate=tx_rate, overrides=overrides,
    )

    # enqueue: write this window's broadcast (payload + per-link metadata)
    # into ring slot widx % D; the bucketed mixing happens at drain time
    slot = jnp.mod(widx, D)
    buffer = jax.lax.dynamic_update_slice(
        state.buffer, pending[None], (slot, 0, 0)
    )
    w_ring = state.w_ring.at[slot].set(w_eff)
    delay_ring = state.delay_ring.at[slot].set(delay_w)

    # senders clear their pending backlog (Lemma A.1 backups are now sent)
    pending = pending * (~tx_mask).astype(jnp.float32)[:, None]

    # --- 4. periodic unification -------------------------------------------
    if cfg.unify_period > 0:
        params, accept_count = _unify(params, accept_count, widx, cfg, n)

    return DracoState(
        params=params,
        pending=pending,
        buffer=buffer,
        w_ring=w_ring,
        delay_ring=delay_ring,
        accept_count=accept_count,
        total_accept=total_accept,
        window_idx=widx + 1,
        key=k_next,
        positions=state.positions if positions is None else positions,
        opt_state=opt_state,
    )


@partial(jax.jit, static_argnames=("cfg", "task", "num_windows"))
def run_windows(state, cfg: DracoConfig, q, adj, task, data, num_windows: int):
    """`task`: a `repro.tasks.Task` or a bare loss callable (legacy);
    `q` (N, N) row-stochastic mixing weights."""
    def step(s, _):
        return draco_window(s, cfg, q, adj, task, data), None

    state, _ = jax.lax.scan(step, state, None, length=num_windows)
    return state


def build_graph(cfg: DracoConfig, key=None):
    adj = adjacency(cfg.topology, cfg.num_clients, key=key)
    q = row_stochastic(adj)
    return q, adj


def virtual_global_model(params):
    """x_bar = E_i[x^(i)] (Sec. 2.1) — evaluation-only."""
    return jax.tree_util.tree_map(lambda p: p.mean(axis=0), params)


# ---------------------------------------------------------------------------
# Seed reference engine (pre-fusion), kept verbatim as the bit-for-bit
# oracle for the fused path (tests/test_protocol_parity.py) and as the
# baseline of `benchmarks.run.bench_draco_window`.  Do not optimize.
# ---------------------------------------------------------------------------


class DracoStateLegacy(NamedTuple):
    params: Any  # leaves (N, ...)
    pending: Any  # accumulated untransmitted local updates (N, ...)
    buffer: Any  # in-flight weighted deltas (D, N, ...)
    accept_count: jax.Array  # (N,) messages accepted this period
    total_accept: jax.Array  # (N,) messages accepted over the whole run
    window_idx: jax.Array  # scalar int32
    key: jax.Array
    positions: jax.Array  # (N, 2) node coordinates (channel model)
    opt_state: jax.Array = ()  # (N, Dopt) f32 — flat local optimizer plane


def init_state_legacy(key, cfg: DracoConfig, params0,
                      task=None) -> DracoStateLegacy:
    """Seed layout: per-leaf pytree buffers of already-mixed deltas.
    `task` sizes the flat optimizer plane exactly as in `init_state`."""
    n, d = cfg.num_clients, cfg.max_delay_windows
    kp, ks = jax.random.split(key)
    params = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape).copy(), params0
    )
    pending = jax.tree_util.tree_map(jnp.zeros_like, params)
    buffer = jax.tree_util.tree_map(
        lambda p: jnp.zeros((d,) + p.shape, p.dtype), params
    )
    pos = channel_lib.place_nodes(kp, n, cfg.channel or ChannelConfig())
    return DracoStateLegacy(
        params=params,
        pending=pending,
        buffer=buffer,
        accept_count=jnp.zeros((n,), jnp.int32),
        total_accept=jnp.zeros((n,), jnp.int32),
        window_idx=jnp.zeros((), jnp.int32),
        key=ks,
        positions=pos,
        opt_state=_opt_plane(task, params0, n),
    )


def draco_window_legacy(state: DracoStateLegacy, cfg: DracoConfig, q, adj,
                        loss_fn, data) -> DracoStateLegacy:
    """Seed window: D-1 per-bucket full-pytree einsums at enqueue time.

    Deliberately self-contained (no code shared with `draco_window`
    beyond the local-update machinery (`local_step`) and `_psi_accept`,
    which predate the fusion), so the parity suite compares two
    independent *gossip engines* rather than one refactor of the other.
    `loss_fn` may be a `repro.tasks.Task` — the oracle for task-layer
    parity runs (the dispatcher keeps the bare-callable graph verbatim).
    `q` (N, N) is the row-stochastic mixing matrix."""
    n, D = cfg.num_clients, cfg.max_delay_windows
    keys = jax.random.split(state.key, 8)
    k_next, k_grad, k_gsel, k_tx, k_chan, k_psi, k_hub, _ = keys
    widx = state.window_idx

    # --- 1. deliveries: drain this window's buffer slot -------------------
    slot = jnp.mod(widx, D)
    arrivals = jax.tree_util.tree_map(lambda b: b[slot], state.buffer)
    params = jax.tree_util.tree_map(
        lambda p, a: p + a.astype(p.dtype), state.params, arrivals
    )
    buffer = jax.tree_util.tree_map(
        lambda b: b.at[slot].set(jnp.zeros_like(b[slot])), state.buffer
    )

    # --- 2. gradient events ------------------------------------------------
    grad_mask = sample_event_masks(k_grad, cfg.lambda_grad, cfg.window, n)
    delta, opt_state = local_step(k_gsel, params, grad_mask, cfg, loss_fn,
                                  data, state.opt_state, widx)
    pending = jax.tree_util.tree_map(lambda a, b: a + b, state.pending, delta)
    if cfg.apply_self_update:
        params = jax.tree_util.tree_map(
            lambda p, dl: p + dl.astype(p.dtype), params, delta
        )

    # --- 3. transmission events + channel ----------------------------------
    tx_mask = sample_event_masks(k_tx, cfg.lambda_tx, cfg.window, n)
    if cfg.channel is not None and cfg.channel.enabled:
        gamma, success = channel_lib.transmission_delays(
            k_chan, state.positions, tx_mask, cfg.channel
        )
        delay_raw = jnp.ceil(gamma / cfg.window).astype(jnp.int32)  # >= 1 typ.
        delay_w = jnp.clip(delay_raw, 1, D - 1)
        # a link spanning >= D windows cannot live in a depth-D ring:
        # dropped (outage), never delivered early at age D-1
        success = success & (delay_raw <= D - 1) & adj
    else:
        success = adj & tx_mask[:, None]
        delay_w = jnp.ones((n, n), jnp.int32)

    accept, accept_count = _psi_accept(k_psi, success, state.accept_count,
                                       cfg.psi)
    # cumulative counter survives the periodic accept_count reset below
    total_accept = state.total_accept + (accept_count - state.accept_count)
    w_eff = q * accept.astype(q.dtype)  # (sender, receiver)

    # enqueue into the ring buffer, bucketed by relative delay
    def enqueue(buf, pend):
        for d in range(1, D):
            w_d = w_eff * (delay_w == d).astype(q.dtype)
            contrib = jnp.einsum("nm,n...->m...", w_d, pend.astype(jnp.float32))
            buf = buf.at[jnp.mod(widx + d, D)].add(contrib.astype(buf.dtype))
        return buf

    buffer = jax.tree_util.tree_map(enqueue, buffer, pending)

    # senders clear their pending backlog (Lemma A.1 backups are now sent)
    keep = (~tx_mask).astype(jnp.float32)
    pending = jax.tree_util.tree_map(
        lambda pnd: pnd * keep.reshape((n,) + (1,) * (pnd.ndim - 1)), pending
    )

    # --- 4. periodic unification -------------------------------------------
    def unify(args):
        p, cnt = args
        hub = jnp.mod((widx // jnp.maximum(cfg.unify_period, 1)), n)
        p = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[hub][None], x.shape), p
        )
        return p, jnp.zeros_like(cnt)

    if cfg.unify_period > 0:
        do_unify = jnp.mod(widx + 1, cfg.unify_period) == 0
        params, accept_count = jax.lax.cond(
            do_unify, unify, lambda a: a, (params, accept_count)
        )

    return DracoStateLegacy(
        params=params,
        pending=pending,
        buffer=buffer,
        accept_count=accept_count,
        total_accept=total_accept,
        window_idx=widx + 1,
        key=k_next,
        positions=state.positions,
        opt_state=opt_state,
    )


@partial(jax.jit, static_argnames=("cfg", "loss_fn", "num_windows"))
def run_windows_legacy(state, cfg: DracoConfig, q, adj, loss_fn, data,
                       num_windows: int):
    """Scan `num_windows` legacy windows; `q` (N, N) row-stochastic."""
    def step(s, _):
        return draco_window_legacy(s, cfg, q, adj, loss_fn, data), None

    state, _ = jax.lax.scan(step, state, None, length=num_windows)
    return state
