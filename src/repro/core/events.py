"""Continuous-timeline Poisson event machinery (paper Sec. 2.3, Assump. 1).

Two views of the same point process:

1. ``event_list``      — exact event-driven timeline (numpy; the faithful
   Algorithm-2 simulator in ``examples/`` and tests uses this).
2. ``window_masks``    — superposition-window discretization: for a window
   of length w, each client fires iff its Poisson process has >= 1 point
   in the window (P = 1 - exp(-lambda w)). This is the JAX-compiled view;
   the superposition window is the paper's own grouping device (Sec. 2.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def window_event_probs(lam, window: float):
    """P(at least one event in a window) per client."""
    return 1.0 - jnp.exp(-jnp.asarray(lam) * window)


def sample_event_masks(key, lam, window: float, n: int):
    """(n,) bool — Poisson thinning to the superposition window."""
    p = window_event_probs(lam, window)
    p = jnp.broadcast_to(p, (n,))
    return jax.random.uniform(key, (n,)) < p


def poisson_truncation_bound(lamw_max: float, sigmas: float = 6.0) -> int:
    """Truncation cap for a Poisson(lam*w) count: mean + `sigmas` std
    deviations (Poisson variance == mean), floored at a small constant so
    near-zero rates still admit the occasional event. At 6 sigma the
    clipped tail mass is negligible (< ~1e-9) at any rate."""
    hi = max(float(lamw_max), 0.0)
    return int(np.ceil(hi + sigmas * np.sqrt(max(hi, 1.0)))) + 1


def sample_event_counts(key, lam, window: float, n: int, max_count=None):
    """(n,) int — number of events in the window (truncated Poisson).

    ``max_count=None`` (the default) sizes the truncation from the rate
    itself via `poisson_truncation_bound` (mean + 6 sigma), so high-rate
    clients keep their tail mass. The old fixed ``max_count=8`` silently
    clipped any client with ``lam*w`` above ~4 — reachable with Pareto
    straggler profiles — biasing its event count low. Passing an explicit
    ``max_count`` keeps the truncated behavior (and is required when
    `lam` is a traced value, since the default needs a concrete rate).
    """
    lamw = jnp.broadcast_to(jnp.asarray(lam) * window, (n,))
    if max_count is None:
        max_count = poisson_truncation_bound(
            float(np.max(np.asarray(lam))) * window)
    return jnp.clip(jax.random.poisson(key, lamw), 0, max_count)


@dataclass
class Event:
    t: float
    client: int
    kind: str  # "grad" | "tx" | "unify"


def unify_hub(k: int, n: int) -> int:
    """Hub of the k-th unification (k = 1, 2, ...) under the rotating-hub
    rule shared with the compiled window engine: `protocol._unify` fires
    at the end of window `widx = k*P - 1` with
    ``hub = (widx // P) % n = (k - 1) % n``."""
    return (k - 1) % n


def event_list(rng: np.random.Generator, n: int, horizon: float,
               lam_grad, lam_tx, unify_period: float = 0.0,
               random_hub: bool = False) -> List[Event]:
    """Exact merged continuous-time event list (Algorithm 2 lines 1-15).

    Unification hubs rotate deterministically (`unify_hub`), matching the
    compiled window engine (`protocol._unify`) so the two unification
    views agree event-for-event; `random_hub=True` restores the legacy
    uniform-random hub draw (one extra rng consumption per unification).
    """
    lam_grad = np.broadcast_to(np.asarray(lam_grad, np.float64), (n,))
    lam_tx = np.broadcast_to(np.asarray(lam_tx, np.float64), (n,))
    events: List[Event] = []
    for i in range(n):
        for lam, kind in ((lam_grad[i], "grad"), (lam_tx[i], "tx")):
            if lam <= 0:
                continue
            t = rng.exponential(1.0 / lam)
            while t < horizon:
                events.append(Event(float(t), i, kind))
                t += rng.exponential(1.0 / lam)
    if unify_period and unify_period > 0:
        k = 1
        while k * unify_period < horizon:
            hub = int(rng.integers(0, n)) if random_hub else unify_hub(k, n)
            events.append(Event(float(k * unify_period), hub, "unify"))
            k += 1
    events.sort(key=lambda e: e.t)
    return events
