"""Flat parameter plane: the per-client pytree as one contiguous buffer.

Every DRACO protocol quantity with a leading client axis — pending
updates, in-flight payloads, consensus residuals — is a pytree whose
leaves share the same (N, ...) layout.  Mixing, delay-bucketed gossip,
consensus distance and hub unification are all *linear* in the
parameters, so none of them care about leaf boundaries: they are
cheaper and simpler as single contiguous ops on an ``(N, Dflat)``
matrix than as per-leaf ``tree_map`` loops (one GEMM / one reduction
instead of ``num_leaves`` dispatches, and a layout the gossip kernels
can tile directly).

``spec_of`` computes the flattening plan (leaf shapes, dtypes, offsets)
once per run — it is static, hashable metadata that rides through jit
(stored on ``SimContext`` by ``repro.api.make_context``).  ``ravel_clients``
and ``unravel_clients`` are exact: reshape + concatenate, no arithmetic,
so a ravel/unravel round-trip is bit-for-bit at any dtype.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class FlatSpec(NamedTuple):
    """Static flattening plan for a client-stacked pytree.

    Hashable (tuples + treedef only), so it can ride through ``jax.jit``
    as auxiliary data.  ``offsets[i]:offsets[i]+sizes[i]`` is leaf ``i``'s
    column range in the flat ``(N, dim)`` buffer.

    ``opt_dim`` is the per-client flat width of the task's local
    optimizer state, laid out as its own ``(N, opt_dim)`` plane next to
    the ``(N, dim)`` parameter plane (momentum -> ``dim``, adamw ->
    ``2 * dim + 1`` incl. its per-client step counter, plain SGD -> 0;
    see ``repro.tasks.opt_width``).  The
    optimizer plane is never gossiped — it stays client-local — but it
    rides the same contiguous layout so sweeps/sharding treat it
    uniformly.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]  # full leaf shapes, incl. client axis
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]  # per-client flat width of each leaf
    dim: int  # Dflat = sum(sizes)
    opt_dim: int = 0  # Dopt = flat width of the local optimizer state

    @property
    def num_clients(self) -> int:
        return self.shapes[0][0] if self.shapes else 0

    def with_opt(self, opt_dim: int) -> "FlatSpec":
        """The same parameter layout with an optimizer plane of width
        ``opt_dim`` alongside (``repro.api.make_context`` sets this from
        the task's optimizer)."""
        return self._replace(opt_dim=int(opt_dim))


def spec_of(tree) -> FlatSpec:
    """Flattening plan for a pytree whose leaves are (N, ...) arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape[1:], dtype=np.int64)) if leaf.ndim > 1 else 1
        shapes.append(tuple(leaf.shape))
        dtypes.append(jnp.dtype(leaf.dtype))
        offsets.append(off)
        sizes.append(size)
        off += size
    return FlatSpec(treedef, tuple(shapes), tuple(dtypes), tuple(offsets),
                    tuple(sizes), off)


def spec_for(params0, num_clients: int) -> FlatSpec:
    """Plan for a *single-client* pytree replicated across ``num_clients``
    (the layout produced by ``protocol.init_state``)."""
    stacked_shapes = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct((num_clients,) + tuple(p.shape), p.dtype),
        params0,
    )
    return spec_of(stacked_shapes)


def ravel_clients(tree, dtype=jnp.float32) -> jax.Array:
    """(N, ...) pytree -> contiguous (N, Dflat) matrix in ``dtype``.

    Pure reshape + concat (exact at matching dtype); leaf order follows
    ``jax.tree_util`` flattening, matching ``spec_of``.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    n = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(n, -1).astype(dtype) for l in leaves], axis=1
    )


def unravel_clients(flat: jax.Array, spec: FlatSpec):
    """`flat` (N, Dflat) matrix -> pytree per ``spec`` (dtypes restored)."""
    leaves = []
    for shape, dtype, off, size in zip(spec.shapes, spec.dtypes,
                                       spec.offsets, spec.sizes):
        leaves.append(flat[:, off:off + size].reshape(shape).astype(dtype))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)
