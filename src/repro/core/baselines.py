"""The paper's four comparison baselines (Sec. 5):

  - sync-symm : synchronous decentralized SGD w/ symmetric doubly-
                stochastic mixing (Choco-SGD-style exact communication)
  - sync-push : synchronous push-sum over the directed graph
  - async-symm: asynchronous (partial participation + delay deadline)
                with symmetric mixing among surviving links
  - async-push: asynchronous push-sum gossip (Digest-style)

All share DRACO's local-update machinery (`protocol.local_step`) so
comparisons isolate the *communication protocol*, not the optimizer:
the workload slot of every round accepts a bare loss callable (legacy
plain SGD, compiled graph unchanged) or a `repro.tasks.Task`, whose
local optimizer state rides the flat `(N, Dopt)` plane on
`BaselineState.opt_state` exactly as on `DracoState`.

.. deprecated::
   The module-level entry points (`init_baseline_state` / `run_baseline`
   / `eval_params`) remain as the implementation substrate, but new code
   should drive any baseline through the unified interface:
   `repro.api.simulate("sync-push", ...)` etc. — every method in
   `BASELINES` is a registered `repro.api` Algorithm. These names are
   kept so existing imports continue to work.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import channel as channel_lib
from repro.core.channel import ChannelConfig
from repro.core.protocol import DracoConfig, _opt_plane, local_step
from repro.core.topology import adjacency, metropolis


class BaselineState(NamedTuple):
    params: Any  # (N, ...)
    push_weight: jax.Array  # (N,) push-sum weights (1.0 for symm methods)
    key: jax.Array
    round_idx: jax.Array
    positions: jax.Array
    opt_state: jax.Array = ()  # (N, Dopt) f32 — flat local optimizer plane


def init_baseline_state(key, cfg: DracoConfig, params0,
                        task=None) -> BaselineState:
    """`task` (a `repro.tasks.Task`) sizes the flat optimizer plane; None
    or a bare loss callable keeps the plain-SGD (N, 0) layout."""
    n = cfg.num_clients
    kp, ks = jax.random.split(key)
    params = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape).copy(), params0
    )
    pos = channel_lib.place_nodes(kp, n, cfg.channel or ChannelConfig())
    return BaselineState(
        params=params,
        push_weight=jnp.ones((n,)),
        key=ks,
        round_idx=jnp.zeros((), jnp.int32),
        positions=pos,
        opt_state=_opt_plane(task, params0, n),
    )


def _link_success(key, state, cfg, adj, tx_mask, positions=None):
    """Per-round surviving directed links (i->j) incl. channel drops.

    `positions`, when given (mobility scenarios), overrides the state-
    carried node coordinates for this round's channel draws."""
    if cfg.channel is not None and cfg.channel.enabled:
        pos = state.positions if positions is None else positions
        _, success = channel_lib.transmission_delays(
            key, pos, tx_mask, cfg.channel
        )
        return success & adj
    return adj & tx_mask[:, None]


def _participation(key, n, p_base, compute_rate):
    """Per-client participation mask at probability p_base, scaled by a
    scenario's compute-rate ring (clipped into [0, 1]): stragglers show
    up less often. compute_rate=None keeps the frozen-path draw."""
    p = p_base if compute_rate is None else jnp.clip(p_base * compute_rate, 0.0, 1.0)
    return jax.random.uniform(key, (n,)) < p


def _sync_round_keys(state, n, compute_rate):
    """Key split + compute gate shared by the sync rounds. The split
    count is gated on `compute_rate is None` so the frozen path keeps
    its exact legacy RNG stream (the parity suite pins it bit-for-bit);
    only scenario runs pay the extra participation draw."""
    if compute_rate is None:
        k_next, k_g, k_c = jax.random.split(state.key, 3)
        return k_next, k_g, k_c, jnp.ones((n,), bool)
    k_next, k_g, k_c, k_s = jax.random.split(state.key, 4)
    return k_next, k_g, k_c, _participation(k_s, n, 1.0, compute_rate)


def _advance(state, *, params, key, push_weight=None, positions=None,
             opt_state=None):
    """Shared end-of-round state update (positions track mobility)."""
    kw = dict(params=params, key=key, round_idx=state.round_idx + 1)
    if push_weight is not None:
        kw["push_weight"] = push_weight
    if positions is not None:
        kw["positions"] = positions
    if opt_state is not None:
        kw["opt_state"] = opt_state
    return state._replace(**kw)


def _mix_rows(w, params):
    return jax.tree_util.tree_map(
        lambda p: jnp.einsum("ij,j...->i...", w.astype(jnp.float32), p.astype(jnp.float32)).astype(p.dtype),
        params,
    )


def sync_symm_round(state: BaselineState, cfg, w_sym, adj, task, data, *,
                    positions=None, compute_rate=None, lr=None):
    """D-SGD with Metropolis weights; dropped links' mass folds into self.

    `task`: a `repro.tasks.Task` or a bare loss callable (legacy plain
    SGD). A scenario compute-rate ring turns into a per-round completion
    probability: stragglers skip their local update (their stale params
    still mix) — sync methods *wait* for nobody here, matching DRACO's
    compute/comms decoupling rather than stalling the round."""
    n = cfg.num_clients
    all_on = jnp.ones((n,), bool)
    k_next, k_g, k_c, on = _sync_round_keys(state, n, compute_rate)
    delta, opt_state = local_step(k_g, state.params, on, cfg, task, data,
                                  state.opt_state, state.round_idx, lr=lr)
    params = jax.tree_util.tree_map(lambda p, d: p + d.astype(p.dtype), state.params, delta)
    succ = _link_success(k_c, state, cfg, adj, all_on, positions=positions)
    succ = succ & succ.T  # symmetric methods need bidirectional links
    w = jnp.where(succ & ~jnp.eye(n, dtype=bool), w_sym, 0.0)
    # dropped links' weight folds back into the self-loop (keeps w row-stoch.)
    w = jnp.where(jnp.eye(n, dtype=bool), 1.0 - w.sum(axis=1, keepdims=True), w)
    params = _mix_rows(w, params)
    return _advance(state, params=params, key=k_next, positions=positions,
                    opt_state=opt_state)


def sync_push_round(state: BaselineState, cfg, adj, task, data, *,
                    positions=None, compute_rate=None, lr=None):
    """Synchronous push-sum (stochastic gradient push, Assran et al.)."""
    n = cfg.num_clients
    all_on = jnp.ones((n,), bool)
    k_next, k_g, k_c, on = _sync_round_keys(state, n, compute_rate)
    delta, opt_state = local_step(k_g, state.params, on, cfg, task, data,
                                  state.opt_state, state.round_idx, lr=lr)
    params = jax.tree_util.tree_map(lambda p, d: p + d.astype(p.dtype), state.params, delta)
    succ = _link_success(k_c, state, cfg, adj, all_on, positions=positions)
    # column-stochastic P: sender splits mass over (self + successful out-links)
    out = succ.astype(jnp.float32)
    col = out + jnp.eye(n)
    colP = col / col.sum(axis=1, keepdims=True)  # row i: how i splits its mass
    # z_j = sum_i colP[i,j] * z_i  (transpose mixing)
    params = _mix_rows(colP.T, params)
    w = colP.T @ state.push_weight
    de_biased = jax.tree_util.tree_map(
        lambda p: (p.astype(jnp.float32) / w.reshape((n,) + (1,) * (p.ndim - 1))).astype(p.dtype),
        params,
    )
    return _advance(state, params=params, key=k_next, push_weight=w,
                    positions=positions, opt_state=opt_state), de_biased


def async_symm_round(state: BaselineState, cfg, w_sym, adj, task, data,
                     p_active: float = 0.5, *, positions=None,
                     compute_rate=None, lr=None):
    """Async decentralized SGD w/ delay deadline [15]: only a random subset
    is active per round; symmetric mixing among surviving active links.
    A scenario compute-rate ring scales each client's activation
    probability (stragglers participate less often)."""
    n = cfg.num_clients
    k_next, k_a, k_g, k_c = jax.random.split(state.key, 4)
    active = _participation(k_a, n, p_active, compute_rate)
    delta, opt_state = local_step(k_g, state.params, active, cfg, task, data,
                                  state.opt_state, state.round_idx, lr=lr)
    params = jax.tree_util.tree_map(lambda p, d: p + d.astype(p.dtype), state.params, delta)
    succ = _link_success(k_c, state, cfg, adj, active, positions=positions)
    succ = succ & succ.T & active[:, None] & active[None, :]
    w = jnp.where(succ, w_sym, 0.0)
    w = jnp.where(jnp.eye(n, dtype=bool), 1.0 - w.sum(axis=1), w)
    params = _mix_rows(w, params)
    return _advance(state, params=params, key=k_next, positions=positions,
                    opt_state=opt_state)


def async_push_round(state: BaselineState, cfg, adj, task, data,
                     p_active: float = 0.5, *, positions=None,
                     compute_rate=None, lr=None):
    """Asynchronous push-sum gossip (Digest-style [50]): active clients
    push half their mass, split across successful out-neighbors."""
    n = cfg.num_clients
    k_next, k_a, k_g, k_c = jax.random.split(state.key, 4)
    active = _participation(k_a, n, p_active, compute_rate)
    delta, opt_state = local_step(k_g, state.params, active, cfg, task, data,
                                  state.opt_state, state.round_idx, lr=lr)
    params = jax.tree_util.tree_map(lambda p, d: p + d.astype(p.dtype), state.params, delta)
    succ = _link_success(k_c, state, cfg, adj, active, positions=positions)
    out = succ.astype(jnp.float32)
    outdeg = out.sum(axis=1, keepdims=True)
    send = jnp.where(outdeg > 0, 0.5 * out / jnp.maximum(outdeg, 1e-9), 0.0)
    keep = jnp.where(outdeg[:, 0] > 0, 0.5, 1.0)
    P = send + jnp.diag(keep)  # row-(sub)stochastic mass split
    params = _mix_rows(P.T, params)
    w = P.T @ state.push_weight
    de_biased = jax.tree_util.tree_map(
        lambda p: (p.astype(jnp.float32) / w.reshape((n,) + (1,) * (p.ndim - 1))).astype(p.dtype),
        params,
    )
    return _advance(state, params=params, key=k_next, push_weight=w,
                    positions=positions, opt_state=opt_state), de_biased


BASELINES = ("sync-symm", "sync-push", "async-symm", "async-push")


@partial(jax.jit, static_argnames=("method", "cfg", "loss_fn", "num_rounds"))
def run_baseline(method: str, state, cfg: DracoConfig, loss_fn, data,
                 num_rounds: int, graph_key=None):
    """`loss_fn` may be a bare loss callable or a `repro.tasks.Task`
    (both are hashable static jit keys)."""
    adj = adjacency(cfg.topology, cfg.num_clients, key=graph_key)
    w_sym = metropolis(adj)

    def step(s, _):
        if method == "sync-symm":
            s = sync_symm_round(s, cfg, w_sym, adj, loss_fn, data)
        elif method == "sync-push":
            s, _ = sync_push_round(s, cfg, adj, loss_fn, data)
        elif method == "async-symm":
            s = async_symm_round(s, cfg, w_sym, adj, loss_fn, data)
        elif method == "async-push":
            s, _ = async_push_round(s, cfg, adj, loss_fn, data)
        else:
            raise ValueError(method)
        return s, None

    state, _ = jax.lax.scan(step, state, None, length=num_rounds)
    return state


def eval_params(method: str, state: BaselineState):
    """Method-appropriate evaluation params (push methods de-bias)."""
    if method.endswith("push"):
        n = state.push_weight.shape[0]
        return jax.tree_util.tree_map(
            lambda p: (p.astype(jnp.float32) / state.push_weight.reshape((n,) + (1,) * (p.ndim - 1))).astype(p.dtype),
            state.params,
        )
    return state.params
