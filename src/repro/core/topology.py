"""Gossip graph topologies and row-stochastic weight matrices.

The paper (Sec. 2.2) normalizes transmission weights across *receivers*:
``sum_{j != i} q^{ij} = 1`` for every sender i — i.e. Q is **row**-
stochastic with zero diagonal, and no symmetry/doubly-stochastic
assumption (directed graphs allowed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def adjacency(topology: str, n: int, key=None, p: float = 0.3, directed: bool = False):
    """Boolean (n, n) adjacency, zero diagonal."""
    if topology == "cycle":
        a = np.zeros((n, n), bool)
        for i in range(n):
            a[i, (i + 1) % n] = True
            if not directed:
                a[i, (i - 1) % n] = True
    elif topology == "ring2d":  # 2D torus (matches TPU ICI topology)
        side = int(round(np.sqrt(n)))
        assert side * side == n, "ring2d needs square n"
        a = np.zeros((n, n), bool)
        for i in range(n):
            r, c = divmod(i, side)
            for dr, dc in ((0, 1), (1, 0), (0, -1), (-1, 0)):
                j = ((r + dr) % side) * side + (c + dc) % side
                a[i, j] = True
    elif topology == "complete":
        a = ~np.eye(n, dtype=bool)
    elif topology == "star":
        a = np.zeros((n, n), bool)
        a[0, 1:] = True
        a[1:, 0] = True
    elif topology == "erdos":
        assert key is not None
        rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
        a = rng.random((n, n)) < p
        np.fill_diagonal(a, False)
        if not directed:
            a = a | a.T
        # connectivity overlay: a directed Hamiltonian cycle makes the
        # digraph strongly connected; mirror it for undirected graphs so
        # the adjacency stays symmetric (the one-way overlay used to
        # leave "undirected" erdos graphs asymmetric)
        for i in range(n):
            a[i, (i + 1) % n] = True
            if not directed:
                a[(i + 1) % n, i] = True
    else:
        raise ValueError(topology)
    np.fill_diagonal(a, False)
    return jnp.asarray(a)


def row_stochastic(adj, weights=None) -> jax.Array:
    """Row-stochastic Q from adjacency (uniform over out-neighbors)."""
    a = adj.astype(jnp.float32)
    if weights is not None:
        a = a * weights
    deg = a.sum(axis=1, keepdims=True)
    return jnp.where(deg > 0, a / jnp.maximum(deg, 1e-9), 0.0)


def metropolis(adj) -> jax.Array:
    """Symmetric doubly-stochastic Metropolis-Hastings weights (for the
    sync-symm / async-symm baselines, which assume undirected graphs)."""
    a = adj | adj.T
    deg = a.sum(axis=1)
    w = jnp.where(a, 1.0 / (1.0 + jnp.maximum(deg[:, None], deg[None, :])), 0.0)
    self_w = 1.0 - w.sum(axis=1)
    return w + jnp.diag(self_w)


def is_row_stochastic(q, atol=1e-5) -> bool:
    rows = q.sum(axis=1)
    nonzero = rows > atol
    ok_rows = jnp.abs(jnp.where(nonzero, rows, 1.0) - 1.0) < atol
    return bool(jnp.all(q >= -atol) & jnp.all(ok_rows) & jnp.all(jnp.diag(q) < atol))
