"""Gossip aggregation: ``x_j += sum_i q[i,j] * delta_i``.

Three lowering strategies for the same row-stochastic semantics:

  - ``mix_dense``   : einsum against the full (N, N) Q. With the client
    axis sharded over ("pod","data") this lowers to all-gather +
    local matmul — the paper-faithful baseline (arbitrary digraphs).
  - ``mix_psi_topk``: applies the paper's Psi cap by keeping only the
    top-Psi incoming weights per receiver before mixing. On the mesh this
    bounds collective bytes per window — the paper's communication-budget
    knob becomes an ICI-bandwidth knob.
  - ``mix_ring``    : shard_map + lax.ppermute for cycle/ring topologies —
    gossip edges map 1:1 onto ICI torus links (beyond-paper optimization;
    no all-gather, 2 neighbor permutes).

All operate on pytrees whose leaves have a leading client axis N.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.gossip import ops as gossip_ops


def _resolve_shard_map():
    """Version-tolerant shard_map lookup: top-level `jax.shard_map` on
    recent JAX, `jax.experimental.shard_map.shard_map` on older releases."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map as sm

    return sm


def receive_counts(q_mask) -> jax.Array:
    """Messages incoming per receiver j: count of nonzero column entries."""
    return (q_mask > 0).sum(axis=0)


def psi_cap_mask(key, q, psi: int):
    """Keep at most `psi` incoming edges per receiver (column-wise top-psi
    by weight with random tie-break), zeroing the rest. Returns masked q.

    Uses argsort ranking (strict order even under exact weight ties)."""
    n = q.shape[0]
    if psi >= n:
        return q
    noise = jax.random.uniform(key, q.shape, minval=0.0, maxval=1e-6)
    score = jnp.where(q > 0, q + noise, -jnp.inf)  # (sender, receiver)
    order = jnp.argsort(-score, axis=0)  # per receiver: best sender first
    rank = jnp.zeros((n, n), jnp.int32)
    rank = rank.at[order, jnp.arange(n)[None, :]].set(
        jnp.broadcast_to(jnp.arange(n)[:, None], (n, n))
    )
    keep = (rank < psi) & (q > 0)
    return jnp.where(keep, q, 0.0)


def mix_dense(q_eff, deltas, *, use_kernel=None, interpret=None,
              compute_dtype=jnp.float32):
    """x_add = Q^T @ deltas on the flat plane. q_eff (N,N) masked/weighted.

    The per-client pytree is raveled to one contiguous (N, Dflat) matrix
    (`repro.core.flat`), mixed with a single GEMM — the Pallas gossip
    kernel on TPU (`use_kernel=None` auto-selects by backend), a plain
    einsum elsewhere — and unraveled back, instead of one einsum per leaf.

    compute_dtype: accumulation dtype of the mixing matmul. f32 is the
    paper-faithful default; bf16 halves the all-gather bytes on the mesh
    (beyond-paper knob, see EXPERIMENTS.md §Perf)."""
    from repro.core import flat as flat_lib

    if use_kernel is None:
        use_kernel = gossip_ops.default_use_kernel()
    spec = flat_lib.spec_of(deltas)
    flat = flat_lib.ravel_clients(deltas, dtype=compute_dtype)
    if use_kernel:
        out = gossip_ops.gossip_mix(q_eff, flat, interpret=interpret)
    else:
        out = jnp.einsum("nm,nk->mk", q_eff.astype(compute_dtype), flat)
    return flat_lib.unravel_clients(out, spec)


def apply_mix(params, q_eff, deltas, **kw):
    add = mix_dense(q_eff, deltas, **kw)
    return jax.tree_util.tree_map(lambda p, a: p + a.astype(p.dtype), params, add)


# ---------------------------------------------------------------------------
# Ring lowering (cycle topology -> ICI neighbor permutes)
# ---------------------------------------------------------------------------


def mix_ring_shardmap(mesh, client_axes, deltas, w_fwd: float = 0.5, w_bwd: float = 0.5,
                      gate_fwd=None, gate_bwd=None):
    """Cycle-gossip via collective_permute on the client mesh axes.

    Each client receives w_fwd * delta_{i-1} + w_bwd * delta_{i+1}
    (directed ring if one weight is 0). `gate_*` are optional per-client
    (N,) multipliers (event/Psi masks) applied at the *sender*.

    Lowering: two lax.ppermute ops — bytes per device = 2 * |delta|/TP,
    strictly neighbor traffic on the ICI torus (no all-gather). The
    in/out specs preserve each leaf's model-axis sharding (a naive
    P(clients, None, ...) spec forces an all-gather of expert/TP-sharded
    leaves over "model" before the permute — measured regression).
    """
    shard_map = _resolve_shard_map()

    from repro.sharding.specs import param_spec

    axes = client_axes if isinstance(client_axes, tuple) else (client_axes,)
    ax0 = axes if len(axes) > 1 else axes[0]
    in_specs = jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, tuple(leaf.shape), mesh, prefix=(ax0,)),
        deltas,
    )
    gspec = P(ax0)

    n_clients = 1
    for a in axes:
        n_clients *= mesh.shape[a]
    fwd_perm = [(i, (i + 1) % n_clients) for i in range(n_clients)]
    bwd_perm = [(i, (i - 1) % n_clients) for i in range(n_clients)]

    if gate_fwd is None:
        gate_fwd = jnp.ones((n_clients,), jnp.float32)
    if gate_bwd is None:
        gate_bwd = jnp.ones((n_clients,), jnp.float32)

    axis_name = axes[0] if len(axes) == 1 else axes

    def body(d, gf, gb):
        # inside shard_map: leading client axis has local size 1
        def leaf(x, gfl, gbl):
            gfl = gfl.reshape((1,) + (1,) * (x.ndim - 1))
            gbl = gbl.reshape((1,) + (1,) * (x.ndim - 1))
            # fwd_perm: i -> i+1, so after the permute each client holds the
            # value its ring-predecessor sent (the forward edge j-1 -> j).
            xf = jax.lax.ppermute(x * gfl.astype(x.dtype), axis_name=axis_name, perm=fwd_perm)
            xb = jax.lax.ppermute(x * gbl.astype(x.dtype), axis_name=axis_name, perm=bwd_perm)
            return (w_fwd * xf + w_bwd * xb).astype(x.dtype)

        return jax.tree_util.tree_map(lambda x: leaf(x, gf, gb), d)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(in_specs, gspec, gspec),
        out_specs=in_specs,
    )
    return fn(deltas, gate_fwd, gate_bwd)
