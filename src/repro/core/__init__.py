from repro.core.protocol import (
    DracoConfig,
    DracoState,
    build_graph,
    draco_window,
    init_state,
    run_windows,
    virtual_global_model,
)

__all__ = [
    "DracoConfig",
    "DracoState",
    "build_graph",
    "draco_window",
    "init_state",
    "run_windows",
    "virtual_global_model",
]
