from repro.core.flat import (
    FlatSpec,
    ravel_clients,
    spec_for,
    spec_of,
    unravel_clients,
)
from repro.core.protocol import (
    DracoConfig,
    DracoState,
    DracoStateLegacy,
    build_graph,
    draco_window,
    draco_window_legacy,
    init_state,
    init_state_legacy,
    run_windows,
    run_windows_legacy,
    virtual_global_model,
)

__all__ = [
    "DracoConfig",
    "DracoState",
    "DracoStateLegacy",
    "FlatSpec",
    "build_graph",
    "draco_window",
    "draco_window_legacy",
    "init_state",
    "init_state_legacy",
    "ravel_clients",
    "run_windows",
    "run_windows_legacy",
    "spec_for",
    "spec_of",
    "unravel_clients",
    "virtual_global_model",
]
