"""PartitionSpec rules for parameter pytrees.

``param_spec(path, shape, mesh, prefix)`` maps a parameter's key-path +
shape to a PartitionSpec. Core rule set (tensor-parallel over "model"):

  - projections *into* the sharded dim (wq/wk/wv/w_gate/w_up, ssm
    in_proj): last dim on "model"
  - projections *out of* the sharded dim (wo/w_down/ssm out_proj):
    first core dim on "model"
  - expert-stacked weights: expert axis on "model"
  - embeddings: vocab on "model"; norms/biases/scalars replicated

Axes whose dim is not divisible by the mesh-axis size fall back to
replicated (jax.jit in_shardings require exact divisibility). Leading
stack axes (client axis, layer-group axis) are covered by ``prefix``
(padded with None up to the leaf rank).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import PartitionSpec as P

# (name fragment, core spec aligned to the LAST len(spec) dims)
_RULES: Tuple[Tuple[str, tuple], ...] = (
    ("embed", ("model", None)),
    ("lm_head", (None, "model")),
    ("wq", (None, "model")),
    ("wk", (None, "model")),
    ("wv", (None, "model")),
    ("wo", ("model", None)),
    ("w_gate", (None, "model")),
    ("w_up", (None, "model")),
    ("w_down", ("model", None)),
    # MoE: stacked (E, d, f)/(E, f, d) -> shard expert axis.
    ("experts_gate", ("model", None, None)),
    ("experts_up", ("model", None, None)),
    ("experts_down", ("model", None, None)),
    ("router", (None, None)),
    # SSD / Mamba2
    ("in_proj", (None, "model")),
    ("out_proj", ("model", None)),
    ("conv_w", ("model", None)),
    ("conv_b", ("model",)),
    ("a_log", ("model",)),
    ("ssm_d", ("model",)),
    ("dt_bias", ("model",)),
    ("gnorm", ("model",)),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def filter_divisible(spec: P, shape, mesh) -> P:
    """Replace spec entries whose mesh-axis size doesn't divide the dim."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None
        out.append(ax)
    return P(*out)


def param_spec(path, shape, mesh=None, prefix: tuple = ()) -> P:
    """Spec for one param leaf. `prefix` covers leading stack axes."""
    name = _path_str(path) if not isinstance(path, str) else path
    ndim = len(shape)
    core = None
    for frag, spec in _RULES:
        if frag in name:
            core = spec
            break
    if core is None:
        core = ()  # replicated (norm scales, biases, scalars)
    core = tuple(core)
    n_pad = ndim - len(prefix) - len(core)
    if n_pad < 0:  # leaf rank smaller than rule: replicate the tail
        spec = P(*prefix, *([None] * max(ndim - len(prefix), 0)))
    else:
        spec = P(*prefix, *([None] * n_pad), *core)
    if mesh is not None:
        spec = filter_divisible(spec, shape, mesh)
    return spec


def tree_param_specs(params, prefix: tuple = (), mesh=None):
    """PartitionSpec pytree matching `params` (same treedef)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, tuple(leaf.shape), mesh, prefix),
        params,
    )
