"""Logical-axis sharding context.

Model code calls ``constrain(x, 'batch', 'seq', 'heads', None)`` with
*logical* axis names; the active :class:`AxisRules` maps those to mesh
axes. With no active rules (CPU tests) ``constrain`` is a no-op, so the
model code is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxis = Union[None, str, Tuple[str, ...]]

_TLS = threading.local()


@dataclass(frozen=True)
class AxisRules:
    mesh: Mesh
    rules: dict  # logical name -> mesh axis (str | tuple | None)

    def to_mesh_axes(self, names) -> P:
        axes = []
        for n in names:
            axes.append(None if n is None else self.rules.get(n))
        return P(*axes)


# Production logical->mesh mapping. "clients" is the DRACO agent axis.
def default_rules(mesh: Mesh) -> AxisRules:
    multi_pod = "pod" in mesh.axis_names
    client_axes = ("pod", "data") if multi_pod else ("data",)
    return AxisRules(
        mesh=mesh,
        rules={
            "clients": client_axes if multi_pod else "data",
            "batch": client_axes if multi_pod else "data",  # serving batch
            "seq": None,
            "cache_seq": None,  # overridden to 'data' for long-context decode
            "heads": "model",
            "kv_heads": "model",
            "ff": "model",
            "experts": "model",
            "vocab": "model",
            "embed": None,
            "state": None,
            "ssm_heads": "model",
        },
    )


def train_rules(mesh: Mesh, seq_parallel: bool = False) -> AxisRules:
    """Rules for code running *inside* the per-client vmap: the client axis
    is handled by vmap(spmd_axis_name=...), so logical batch stays
    unsharded and only model-parallel axes constrain.

    seq_parallel=True maps the residual-stream 'seq' axis onto "model"
    (Megatron-style sequence parallelism): the per-layer saved carries of
    the remat'd layer scan shard 16x instead of replicating within the
    tensor-parallel group."""
    base = default_rules(mesh)
    rules = dict(base.rules)
    rules["batch"] = None
    rules["clients"] = None
    if seq_parallel:
        rules["seq"] = "model"
    return AxisRules(mesh=mesh, rules=rules)


def current_rules() -> Optional[AxisRules]:
    return getattr(_TLS, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield rules
    finally:
        _TLS.rules = prev


def constrain(x: jax.Array, *names):
    """Apply a sharding constraint by logical axis names (no-op w/o rules).

    Axes mapped to None and axes whose dim isn't divisible by the mesh-axis
    size become UNCONSTRAINED (partitioner's choice) — NOT replicated: an
    explicit None would force an all-gather of already-sharded operands
    (measured: a full f32 KV-cache all-gather per layer at decode)."""
    from repro.sharding.specs import filter_divisible

    rules = current_rules()
    if rules is None:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    spec = filter_divisible(rules.to_mesh_axes(names), x.shape, rules.mesh)
    # dedup: a mesh axis may appear once; later duplicates -> UNCONSTRAINED
    seen = set()
    axes_out = []
    for a in spec:
        key = tuple(a) if isinstance(a, tuple) else a
        if a is not None and key in seen:
            a = None
        elif a is not None:
            seen.add(key)
        axes_out.append(a)
    U = jax.sharding.PartitionSpec.UNCONSTRAINED
    spec = jax.sharding.PartitionSpec(*[a if a is not None else U for a in axes_out])
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
