from repro.sharding.axes import (
    AxisRules,
    constrain,
    current_rules,
    use_rules,
)
from repro.sharding.specs import param_spec, tree_param_specs

__all__ = [
    "AxisRules",
    "constrain",
    "current_rules",
    "use_rules",
    "param_spec",
    "tree_param_specs",
]
