"""The built-in scenario generators.

Four workload families over the `Schedule` ring abstraction:

  - ``static``          — the frozen t=0 graph as a period-1 ring; the
    parity anchor (bit-for-bit equal to the frozen-graph simulator).
  - ``markov-edge-flip`` — per-edge on/off Markov chains with a tunable
    churn rate and stationary density, re-normalized row-stochastic
    each step (topology as a time-varying control variable, DySTop-
    style).
  - ``random-waypoint``  — node mobility in the deployment disk; the
    adjacency and Q are re-derived from channel geometry each epoch
    (links within range, gossip weights by path-gain), and the position
    ring feeds the wireless channel so per-link delays are redrawn from
    the current geometry.
  - ``straggler-profile`` — frozen graph, time-varying per-client
    compute rates: heavy-tailed (Pareto) slowdowns plus on/off duty
    cycles modulating DRACO's decoupled computation schedule.

All generators precompute host-side with numpy (seeded from a JAX key
exactly like `topology.adjacency("erdos")` does) and return device
rings; nothing here runs inside jit.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as channel_lib
from repro.core.channel import ChannelConfig
from repro.core.topology import adjacency, metropolis, row_stochastic
from repro.scenarios.base import Schedule, register_scenario


def _np_rng(key) -> np.random.Generator:
    if key is None:
        key = jax.random.PRNGKey(0)
    return np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))


def _cycle_overlay(a: np.ndarray) -> np.ndarray:
    """Always-on bidirectional Hamiltonian cycle: keeps every snapshot
    strongly connected (and the symmetrized graph connected for the
    *-symm baselines) no matter how hard the generator churns."""
    n = a.shape[0]
    idx = np.arange(n)
    a[idx, (idx + 1) % n] = True
    a[(idx + 1) % n, idx] = True
    return a


def _rings_from_adjs(adjs, weights=None) -> Schedule:
    """Stack per-step (adj_t[, link weights_t]) into q/adj/w_sym rings."""
    qs, ws = [], []
    for t, a in enumerate(adjs):
        a = jnp.asarray(a)
        qs.append(row_stochastic(a, None if weights is None else weights[t]))
        ws.append(metropolis(a))
    return Schedule(q=jnp.stack(qs), adj=jnp.stack([jnp.asarray(a) for a in adjs]),
                    w_sym=jnp.stack(ws))


@register_scenario("static")
def static(cfg, key=None) -> Schedule:
    """The frozen t=0 graph as a period-1 ring.

    Built from the same `adjacency`/`row_stochastic`/`metropolis` calls
    (and the same `key`) as the frozen `make_context` path, so a static
    scenario run is bit-for-bit identical to the scenario-less simulator
    (`tests/test_scenarios_parity.py` enforces this).
    """
    adj = adjacency(cfg.topology, cfg.num_clients, key=key)
    return Schedule(q=row_stochastic(adj)[None], adj=adj[None],
                    w_sym=metropolis(adj)[None])


@register_scenario("markov-edge-flip")
def markov_edge_flip(cfg, key=None, steps: int = 32, churn: float = 0.1,
                     density: Optional[float] = None,
                     keep_connected: bool = True) -> Schedule:
    """Per-edge on/off Markov chains over all directed pairs.

    Each off-diagonal edge flips between on and off with per-step rates
    chosen so the chain's stationary on-probability equals `density`
    (default: the base topology's own edge density): P(on->off) = churn,
    P(off->on) = churn * density / (1 - density). `churn` therefore
    dials link volatility at constant expected connectivity — churn=0
    freezes the base graph. Step 0 is the base topology itself.

    On dense bases the off->on rate can exceed 1; both rates are then
    scaled down together, which preserves the stationary density exactly
    (the contract a churn sweep relies on) at the cost of saturating the
    effective volatility at its densest-feasible value.
    """
    n = cfg.num_clients
    if key is None:
        key = jax.random.PRNGKey(0)
    k_base, k_chain = jax.random.split(key)
    rng = _np_rng(k_chain)
    base = np.asarray(adjacency(cfg.topology, n, key=k_base)).copy()
    off_diag = ~np.eye(n, dtype=bool)
    if density is None:
        density = float(base[off_diag].mean())
    density = float(np.clip(density, 0.05, 0.95))
    p_on_off = float(np.clip(churn, 0.0, 1.0))
    p_off_on = p_on_off * density / (1.0 - density)
    if p_off_on > 1.0:
        p_on_off, p_off_on = p_on_off / p_off_on, 1.0

    edges = base.copy()
    adjs = []
    for _ in range(int(steps)):
        a = edges & off_diag
        if keep_connected:
            a = _cycle_overlay(a.copy())
        adjs.append(a)
        u = rng.random((n, n))
        edges = np.where(edges, u >= p_on_off, u < p_off_on) & off_diag
    return _rings_from_adjs(adjs)


@register_scenario("random-waypoint")
def random_waypoint(cfg, key=None, steps: int = 32, speed: float = 25.0,
                    comm_radius_frac: float = 0.5, gain_cap: float = 16.0,
                    keep_connected: bool = True) -> Schedule:
    """Random-waypoint mobility: each node moves toward a uniform target
    in the deployment disk at `speed` m/epoch, resampling on arrival.

    The graph is re-derived from channel geometry every epoch: nodes
    within `comm_radius_frac * R` are linked, and Q weights each row by
    path gain (d^-alpha) over the in-range neighbors — nearer neighbors
    carry more gossip mass, exactly as the wireless channel favors them.
    `gain_cap` bounds the weight ratio between the nearest and the
    edge-of-range neighbor (raw d^-4 spans ~9 orders of magnitude and
    would park a whole row's mass on one link, strangling diffusion);
    the cap keeps Q geometry-aware but still mixing. The position ring
    feeds the channel model inside the scan, so per-link delays/drops
    are redrawn from the *current* geometry.
    """
    n = cfg.num_clients
    chan = cfg.channel or ChannelConfig()
    if key is None:
        key = jax.random.PRNGKey(0)
    k_pos, k_wp, k_next = jax.random.split(key, 3)
    rng = _np_rng(k_next)
    pos = np.asarray(channel_lib.place_nodes(k_pos, n, chan)).copy()
    wp = np.asarray(channel_lib.place_nodes(k_wp, n, chan)).copy()

    def sample_wp(m: int) -> np.ndarray:
        r = chan.radius * np.sqrt(rng.random(m))
        th = 2 * np.pi * rng.random(m)
        return np.stack([r * np.cos(th), r * np.sin(th)], axis=-1)

    traj, adjs, gains = [], [], []
    max_range = comm_radius_frac * chan.radius
    for _ in range(int(steps)):
        traj.append(pos.copy())
        dist = np.asarray(channel_lib.pairwise_dist(jnp.asarray(pos)))
        a = np.asarray(channel_lib.geometric_adjacency(jnp.asarray(pos),
                                                       max_range))
        if keep_connected:
            a = _cycle_overlay(a.copy())
        adjs.append(a)
        # path gain relative to the link budget edge: (d / max_range)^-alpha
        # is >= 1 on every in-range link, so row sums stay well above the
        # row_stochastic degree floor no matter the absolute scale of d
        g = (dist / max_range) ** (-chan.path_loss_exp)
        gains.append(jnp.asarray(np.minimum(g, gain_cap), jnp.float32))
        new_pos, arrived = channel_lib.waypoint_step(jnp.asarray(pos),
                                                     jnp.asarray(wp), speed)
        pos = np.asarray(new_pos).copy()
        arrived = np.asarray(arrived)
        if arrived.any():
            wp[arrived] = sample_wp(int(arrived.sum()))
    sched = _rings_from_adjs(adjs, weights=gains)
    return sched._replace(positions=jnp.asarray(np.stack(traj), jnp.float32))


@register_scenario("straggler-profile")
def straggler_profile(cfg, key=None, steps: int = 32,
                      straggler_frac: float = 0.3, slowdown: float = 10.0,
                      duty: float = 1.0, tail: float = 1.5,
                      modulate_tx: bool = False) -> Schedule:
    """Frozen graph, time-varying per-client compute rates.

    A `straggler_frac` subset of clients runs slow: each straggler's
    rate multiplier is 1 / (slowdown * (1 + Pareto(tail))) — heavy-
    tailed, so a few clients are *much* slower than the typical
    straggler — optionally gated by a per-client-phased duty cycle
    (`duty` = fraction of the `steps`-long period the straggler is
    powered at all; 1.0 = always on at the slowed rate). Non-stragglers
    stay at rate 1. The ring multiplies `lambda_grad` in DRACO's
    decoupled computation schedule (and `lambda_tx` too iff
    `modulate_tx`); baselines read it as participation probability.
    """
    n, T = cfg.num_clients, int(steps)
    if key is None:
        key = jax.random.PRNGKey(0)
    k_graph, k_draw = jax.random.split(key)
    rng = _np_rng(k_draw)
    adj = adjacency(cfg.topology, n, key=k_graph)

    num_slow = int(round(np.clip(straggler_frac, 0.0, 1.0) * n))
    slow = np.zeros((n,), bool)
    slow[rng.choice(n, size=num_slow, replace=False)] = True
    factor = np.where(slow, slowdown * (1.0 + rng.pareto(tail, n)), 1.0)
    base_rate = 1.0 / factor  # (n,) in (0, 1], ==1 for non-stragglers

    rate = np.tile(base_rate, (T, 1))
    if duty < 1.0:
        on_steps = max(1, int(round(duty * T)))
        phase = rng.integers(0, T, size=n)
        t_idx = (np.arange(T)[:, None] - phase[None, :]) % T
        powered = (t_idx < on_steps) | ~slow[None, :]  # duty gates stragglers
        rate = rate * powered
    rate = jnp.asarray(rate, jnp.float32)
    return Schedule(q=row_stochastic(adj)[None], adj=adj[None],
                    w_sym=metropolis(adj)[None], compute_rate=rate,
                    tx_rate=rate if modulate_tx else None)
