"""The `Scenario` abstraction: time-varying simulation workloads.

DRACO's claim is stable convergence on *directed, row-stochastic,
asynchronous* networks — but a frozen graph sampled at t=0 only probes
the easiest point of that regime. A scenario turns the simulator into a
workload generator: it produces a (possibly time-varying) stream of

    (q_t, adj_t, positions_t, compute_rate_t, tx_rate_t)

consumed *inside* the jitted `simulate()` scan.

Design: **precomputed schedule rings.** A generator materializes each
stream once, host-side, as a ``(T_field, ...)`` array; inside jit the
step-`t` snapshot is ``field[t % T_field]`` — a dynamic-slice gather,
no recompilation, no host round-trips. Every field rings at its *own*
period, so a straggler profile with a 64-step duty cycle over a frozen
graph stores one ``(1, N, N)`` Q next to a ``(64, N)`` rate ring
instead of tiling the graph 64 times. (The alternative — an in-jit
`lax.switch` over generator bodies — would re-derive Q/Metropolis
weights every window on device; rings pay that cost once and keep the
scan body identical for every scenario.)

Invariants every generator must uphold at **every** scheduled step
(`validate_schedule` checks them; the property suite fuzzes them):
row-stochastic zero-diagonal ``q_t``, boolean zero-diagonal ``adj_t``
with ``q_t`` supported on it, symmetric doubly-stochastic ``w_sym_t``,
and non-negative rate rings.

Generators register with `@register_scenario("name")` — the same
string-keyed singleton idiom as the algorithm registry — and are built
via `make_schedule(name, cfg, key=..., **knobs)`.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np


class Snapshot(NamedTuple):
    """One scheduled step's view of the world, as consumed by step fns.

    `positions`/`compute_rate`/`tx_rate` are None for scenarios that do
    not vary them — step functions then fall back to their frozen-path
    behavior bit-for-bit (state-carried positions, config-rate events).
    """

    q: jax.Array  # (N, N) row-stochastic gossip weights
    adj: jax.Array  # (N, N) bool adjacency
    w_sym: jax.Array  # (N, N) symmetric Metropolis weights
    positions: Optional[jax.Array] = None  # (N, 2) node coordinates
    compute_rate: Optional[jax.Array] = None  # (N,) lambda_grad multiplier
    tx_rate: Optional[jax.Array] = None  # (N,) lambda_tx multiplier


class Schedule(NamedTuple):
    """Precomputed scenario rings; a pytree of device arrays.

    Leading axes are per-field periods: `at(t)` indexes each field by
    ``t % field.shape[0]``, so constant fields are stored once.
    """

    q: jax.Array  # (Tq, N, N)
    adj: jax.Array  # (Tq, N, N) bool
    w_sym: jax.Array  # (Tq, N, N)
    positions: Optional[jax.Array] = None  # (Tp, N, 2)
    compute_rate: Optional[jax.Array] = None  # (Tr, N)
    tx_rate: Optional[jax.Array] = None  # (Tt, N)

    @property
    def period(self) -> int:
        """Longest field period (the schedule repeats after lcm, but the
        max is what tests sweep to see every distinct row)."""
        return max(x.shape[0] for x in self if x is not None)

    @property
    def num_clients(self) -> int:
        return self.q.shape[1]

    def at(self, t) -> Snapshot:
        """Step-`t` snapshot: per-field ring lookup, jit-traceable."""
        t = jnp.asarray(t, jnp.int32)

        def pick(x):
            return None if x is None else x[jnp.mod(t, x.shape[0])]

        return Snapshot(pick(self.q), pick(self.adj), pick(self.w_sym),
                        pick(self.positions), pick(self.compute_rate),
                        pick(self.tx_rate))


GeneratorFn = Callable[..., Schedule]

_REGISTRY: Dict[str, GeneratorFn] = {}


def register_scenario(name: str):
    """Decorator: register `fn(cfg, key=None, **knobs) -> Schedule`."""

    def deco(fn: GeneratorFn) -> GeneratorFn:
        _REGISTRY[name] = fn
        return fn

    return deco


def get_scenario(name: str) -> GeneratorFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def list_scenarios() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_schedule(scenario: Union[str, Schedule], cfg, key=None,
                  **knobs) -> Schedule:
    """Build (or pass through) a `Schedule` for a config.

    `scenario` is a registered generator name or an already-built
    `Schedule`; `key` seeds random structure (graph sampling, mobility,
    straggler draws) exactly like `graph_key` seeds the frozen path.
    """
    if isinstance(scenario, Schedule):
        if knobs:
            raise ValueError("knobs are only valid with a generator name")
        return scenario
    return get_scenario(scenario)(cfg, key=key, **knobs)


def check_snapshot(q, adj, w_sym, atol: float = 1e-5, label: str = "") -> None:
    """Assert the invariant triple on one scheduled step: row-stochastic
    zero-diagonal Q supported on the boolean zero-diagonal adjacency,
    symmetric doubly-stochastic non-negative Metropolis weights. The
    single source of truth — `validate_schedule` and the property suite
    both run exactly this."""
    from repro.core.topology import is_row_stochastic

    assert is_row_stochastic(q), f"q not row-stochastic {label}"
    assert float(jnp.abs(jnp.diag(q)).max()) == 0.0, f"q diagonal {label}"
    assert not bool(jnp.diag(adj).any()), f"adj diagonal {label}"
    assert bool(jnp.all((q > 0) <= adj)), f"q off adj support {label}"
    w = np.asarray(w_sym)
    np.testing.assert_allclose(w, w.T, atol=atol,
                               err_msg=f"w_sym asymmetric {label}")
    np.testing.assert_allclose(w.sum(axis=1), 1.0, atol=atol,
                               err_msg=f"w_sym rows {label}")
    np.testing.assert_allclose(w.sum(axis=0), 1.0, atol=atol,
                               err_msg=f"w_sym cols {label}")
    assert (w >= -atol).all(), f"negative w_sym {label}"


def validate_schedule(sched: Schedule, atol: float = 1e-5) -> None:
    """Assert the scenario invariants at every scheduled step (host-side:
    generators and tests, not jit)."""
    Tq, n, _ = sched.q.shape
    assert sched.adj.shape == (Tq, n, n) and sched.w_sym.shape == (Tq, n, n)
    assert sched.adj.dtype == jnp.bool_
    for t in range(Tq):
        check_snapshot(sched.q[t], sched.adj[t], sched.w_sym[t], atol=atol,
                       label=f"at step {t}")
    if sched.positions is not None:
        assert sched.positions.shape[1:] == (n, 2)
    for rates in (sched.compute_rate, sched.tx_rate):
        if rates is not None:
            assert rates.shape[1:] == (n,)
            assert bool(jnp.all(rates >= 0)), "negative rate ring"
