"""`repro.scenarios` — time-varying simulation workloads.

A `Scenario` generator produces a `Schedule` of precomputed rings
``(q_t, adj_t, positions_t, compute_rate_t, tx_rate_t)`` consumed inside
the jitted `repro.api.simulate` scan via ``schedule.at(step)``:

    from repro.api import simulate
    state, trace = simulate("draco", cfg, params0, loss, train, 600,
                            key=key, scenario="markov-edge-flip",
                            scenario_kwargs={"churn": 0.2})

Built-ins: ``static`` (frozen graph, bit-for-bit equal to the
scenario-less path), ``markov-edge-flip`` (per-edge on/off Markov
chains), ``random-waypoint`` (mobility + geometry-derived graphs),
``straggler-profile`` (heavy-tailed duty-cycled compute rates). New
generators register with `@register_scenario("name")`.
"""
from repro.scenarios.base import (
    Schedule,
    Snapshot,
    check_snapshot,
    get_scenario,
    list_scenarios,
    make_schedule,
    register_scenario,
    validate_schedule,
)

# importing the module registers the built-in generators
from repro.scenarios import generators  # noqa: F401

__all__ = [
    "Schedule",
    "Snapshot",
    "check_snapshot",
    "generators",
    "get_scenario",
    "list_scenarios",
    "make_schedule",
    "register_scenario",
    "validate_schedule",
]
