"""Pytree checkpointing: flattened-key .npz, atomic writes, step indexing.

Layout: <dir>/step_<k>.npz with keys 'path/to/leaf' plus a JSON treedef
sidecar of key order. Restores to host numpy; callers re-shard with
``jax.device_put`` (the trainer does this against its NamedShardings).
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_part(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz can't serialize ml_dtypes
            arr = arr.view(np.uint16)
            key = key + "::bf16"
        out[key] = arr
    return out


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp if tmp.endswith(".npz") else tmp, path)
    # np.savez appends .npz to the tmp name
    if os.path.exists(tmp + ".npz"):
        os.replace(tmp + ".npz", path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for f in os.listdir(ckpt_dir):
        m = re.match(r"step_(\d+)\.npz$", f)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of `tree_like` (shape/dtype template)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    import ml_dtypes

    def lookup(key):
        if key in data:
            return data[key]
        if key + "::bf16" in data:
            return data[key + "::bf16"].view(ml_dtypes.bfloat16)
        raise KeyError(f"checkpoint missing leaf {key}")

    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    out_leaves = []
    for path_t, leaf in leaves_with_path:
        key = _SEP.join(_part(p) for p in path_t)
        arr = lookup(key)
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out_leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, out_leaves)
