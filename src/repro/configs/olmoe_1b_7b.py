"""olmoe-1b-7b — 64 experts top-8 [arXiv:2409.02060].

16L d_model=2048 16H (GQA kv=16) d_ff(expert)=1024 vocab=50304, MoE 64e top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    num_experts=64,
    experts_per_token=8,
    rope_theta=10000.0,
    norm_eps=1e-5,
    source="arXiv:2409.02060 (OLMoE)",
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=4,
        d_ff=96,
        vocab_size=512,
        num_experts=4,
        experts_per_token=2,
        dtype="float32",
        remat=False,
    )
