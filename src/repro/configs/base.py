"""Config system: model configs, input-shape configs, registry.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (the exact assigned scale) and ``reduced()`` (a CPU-smoke-sized
variant of the same family: <=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    ssm_groups: int = 1

    # --- hybrid (zamba2-style shared attention block) ---
    shared_attn_every: int = 0  # insert shared attn block after every k ssm layers

    # --- vlm ---
    cross_attn_every: int = 0  # a cross-attn layer every k layers
    num_patch_tokens: int = 0  # stub vision frontend token count

    # --- audio ---
    embeds_in: bool = False  # inputs are precomputed frame embeddings

    # --- common ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"  # params/activations dtype for production runs
    remat: bool = True
    sliding_window: int = 0  # 0 = full attention; >0 = window (used @ long ctx)
    source: str = ""  # citation for the assigned config

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def d_inner(self) -> int:  # SSD inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- analytics -------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (matches init to within ties/norms)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = V * d  # embed
        if not self.tie_embeddings:
            total += V * d  # lm head

        def attn_params() -> int:
            p = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
            if self.qkv_bias:
                p += (n_q + 2 * n_kv) * hd
            return p

        def mlp_params(ff: int) -> int:
            return 3 * d * ff  # gated SwiGLU

        def ssm_params() -> int:
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            g = self.ssm_groups
            conv_ch = di + 2 * g * ns
            p = d * (2 * di + 2 * g * ns + nh)  # in_proj -> z,x,B,C,dt
            p += conv_ch * self.ssm_conv_width  # depthwise conv
            p += nh * 2 + di  # A_log, D, gated-norm scale
            p += di * d  # out_proj
            return p

        per_layer = 0
        if self.family in ("dense", "vlm", "audio"):
            per_layer = attn_params() + mlp_params(self.d_ff) + 2 * d
        elif self.family == "moe":
            per_layer = (
                attn_params()
                + self.num_experts * mlp_params(self.d_ff)
                + d * self.num_experts  # router
                + 2 * d
            )
        elif self.family == "ssm":
            per_layer = ssm_params() + d
        elif self.family == "hybrid":
            per_layer = ssm_params() + d
        total += L * per_layer
        if self.family == "hybrid" and self.shared_attn_every:
            total += attn_params() + mlp_params(self.d_ff) + 2 * d  # shared once
        if self.family == "vlm" and self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            total += n_cross * (attn_params() + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        dense = self.param_count() - L * self.num_experts * 3 * d * self.d_ff
        return dense + L * self.experts_per_token * 3 * d * self.d_ff


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = (
    "mamba2_2p7b",
    "qwen3_moe_30b_a3b",
    "stablelm_3b",
    "zamba2_2p7b",
    "qwen2p5_32b",
    "qwen2_1p5b",
    "yi_34b",
    "olmoe_1b_7b",
    "llama3p2_vision_11b",
    "musicgen_large",
)

# CLI-facing ids (dashes) -> module names
ARCH_ALIASES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "stablelm-3b": "stablelm_3b",
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen2.5-32b": "qwen2p5_32b",
    "qwen2-1.5b": "qwen2_1p5b",
    "yi-34b": "yi_34b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama-3.2-vision-11b": "llama3p2_vision_11b",
    "musicgen-large": "musicgen_large",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ARCH_ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod_name = ARCH_ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
