from repro.configs.base import (
    ARCH_ALIASES,
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    all_configs,
    get_config,
    get_reduced,
)

__all__ = [
    "ARCH_ALIASES",
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "all_configs",
    "get_config",
    "get_reduced",
]
