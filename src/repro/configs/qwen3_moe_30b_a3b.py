"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768 vocab=151936, MoE 128e top-8.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    num_experts=128,
    experts_per_token=8,
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
    source="hf:Qwen/Qwen3-30B-A3B",
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=96,
        vocab_size=512,
        num_experts=4,
        experts_per_token=2,
        dtype="float32",
        remat=False,
    )
