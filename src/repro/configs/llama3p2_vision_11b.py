"""llama-3.2-vision-11b — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
The ViT/SigLIP vision encoder + projector is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings (batch, 1600, d_model).
A cross-attention layer is inserted every 5 layers (8 cross-attn layers).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    cross_attn_every=5,
    num_patch_tokens=1600,
    rope_theta=500_000.0,
    norm_eps=1e-5,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        cross_attn_every=2,
        num_patch_tokens=16,
        dtype="float32",
        remat=False,
    )
