"""zamba2-2.7b — Mamba2 + shared attention blocks [arXiv:2411.15242].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
A single *shared* transformer (attn+MLP) block is applied after every 6
Mamba2 layers (9 applications over 54 layers), following Zamba2's
parameter-sharing design.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    shared_attn_every=6,
    norm_eps=1e-5,
    source="arXiv:2411.15242 (Zamba2)",
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=512,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_chunk=32,
        shared_attn_every=2,
        dtype="float32",
        remat=False,
    )
