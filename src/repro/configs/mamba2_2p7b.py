"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    norm_eps=1e-5,
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba-2 SSD)",
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2,
        d_model=256,
        vocab_size=512,
        ssm_state=32,
        ssm_head_dim=32,
        ssm_chunk=32,
        dtype="float32",
        remat=False,
    )
