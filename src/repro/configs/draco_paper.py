"""Paper-faithful experiment configs (Section 5 of DRACO).

The paper trains a small CNN: 596,776 bytes (0.57 MB, ~149k fp32 params)
on EMNIST (47 classes) and 51,640 bytes (~12.9k params) on Poker hand
(10 classes). We reproduce with same-parameter-scale models on synthetic
class-conditional data of matched dimensionality (datasets are offline).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class PaperTaskConfig:
    name: str
    input_dim: int
    num_classes: int
    hidden: tuple
    # DRACO simulation defaults (Section 5)
    num_clients: int = 25
    batch_size: int = 64
    local_batches: int = 1  # B
    samples_per_client: int = 1000
    lambda_grad: float = 0.1  # Assumption 1 rate
    lr: float = 0.05
    message_bytes: int = 0


# EMNIST-like: 28x28 inputs, 47 classes, cycle topology in the paper.
EMNIST = PaperTaskConfig(
    name="emnist",
    input_dim=784,
    num_classes=47,
    hidden=(160, 100),
    message_bytes=596_776,
)

# Poker-hand-like: 10 categorical features, 10 classes, complete topology.
POKER = PaperTaskConfig(
    name="poker",
    input_dim=10,
    num_classes=10,
    hidden=(64, 64),
    message_bytes=51_640,
)

TASKS = {"emnist": EMNIST, "poker": POKER}
