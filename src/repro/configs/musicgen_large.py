"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.
The mel-spectrogram + EnCodec conv frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings (batch, seq, d_model)
which the decoder backbone consumes directly; the LM head predicts EnCodec
codebook tokens (vocab 2048).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    embeds_in=True,
    rope_theta=10000.0,
    norm_eps=1e-5,
    source="arXiv:2306.05284 (MusicGen)",
)


def reduced() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        d_ff=512,
        vocab_size=128,
        dtype="float32",
        remat=False,
    )
