"""Staleness-adaptive mixing weights s(delta_tau) (FedAsync families).

The three damping families from the FedAsync line of work, applied to
*gossip* rather than server aggregation: an arriving message whose
payload is ``delta_tau`` superposition windows old has its row-stochastic
weight scaled by ``s(delta_tau)``. ``constant`` is the identity (DRACO's
own semantics); ``hinge`` tolerates a grace period ``b`` then decays
hyperbolically; ``poly`` decays polynomially from the start.

Two consumers:
  - the event engine damps per-message at drain time with the *exact*
    continuous age ``(t_now - t_sent) / window`` (`staleness_fn`);
  - the windowed engine damps per delay bucket with the integer age via
    the `damping=` hook of `core.protocol.draco_window`
    (`staleness_damping_vector`).
"""
from __future__ import annotations

import jax.numpy as jnp


def staleness_scale(mode: str, dtau, a: float = 0.5, b: float = 4.0):
    """s(delta_tau) for one family; elementwise over `dtau` (windows)."""
    dtau = jnp.asarray(dtau, jnp.float32)
    if mode == "constant":
        return jnp.ones_like(dtau)
    if mode == "hinge":
        # FedAsync hinge: continuous at the grace period b and <= 1
        return 1.0 / (a * jnp.maximum(dtau - b, 0.0) + 1.0)
    if mode == "poly":
        return (dtau + 1.0) ** jnp.float32(-a)
    raise ValueError(f"unknown staleness mode {mode!r}")


def staleness_fn(cfg):
    """The config's damping closure ``dtau -> s(dtau)``, or None when the
    family is constant (None keeps the undamped path bit-for-bit)."""
    mode = getattr(cfg, "staleness", "constant")
    if mode == "constant":
        return None
    a = getattr(cfg, "staleness_a", 0.5)
    b = getattr(cfg, "staleness_b", 4.0)
    return lambda dtau: staleness_scale(mode, dtau, a, b)


def staleness_damping_vector(cfg):
    """Age-indexed ``(D,)`` damping vector for the windowed drain hook.

    Entry ``j`` scales the delay bucket whose messages are ``j`` windows
    old (entry 0 is never drained — the ring walks ages 1..D-1). None
    for the constant family, keeping `draco_window` bit-for-bit.
    """
    fn = staleness_fn(cfg)
    if fn is None:
        return None
    ages = jnp.arange(cfg.max_delay_windows, dtype=jnp.float32)
    return fn(ages)
