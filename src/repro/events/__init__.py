"""`repro.events` — the continuous-time event engine.

The windowed engine discretizes DRACO's merged Poisson point process
into superposition windows; this subsystem keeps the exact timeline:

    from repro.events import EventConfig, simulate_events

    cfg = EventConfig(num_clients=25, staleness="poly")
    state, trace = simulate_events("fedasync-gossip", cfg,
                                   task="linear-softmax", horizon=200.0,
                                   key=key, eval_every=500)

Pieces: `tape` pre-samples each run into a sorted fixed-length
`EventTape`; `engine` scans it with per-event `lax.switch` dispatch over
the flat parameter plane and the fused `gossip_drain`; `replay` is the
step-by-step eager oracle (bit-for-bit); `algorithms` registers the
family (draco-event, fedasync-gossip, event-triggered, fedasync-window);
`driver` routes everything through the unified `repro.api.simulate`
scan, so `simulate_sweep` grids work unchanged.
"""
from repro.events.config import EventConfig, STALENESS_MODES
from repro.events.tape import (
    EventTape,
    KIND_GRAD,
    KIND_TX,
    KIND_UNIFY,
    profiled_event_list,
    sample_event_tape,
    tape_capacity,
    tape_from_events,
)
from repro.events.staleness import (
    staleness_damping_vector,
    staleness_fn,
    staleness_scale,
)
from repro.events.engine import EventState, event_step, init_event_state
from repro.events.replay import ReplayResult, replay_events
from repro.events.driver import events_context, simulate_events

# importing the module registers the event algorithm family. Keep this
# AFTER the driver import: it pulls in repro.api, whose __init__
# re-exports driver names from this (then partially-initialized) module.
from repro.events import algorithms  # noqa: F401  (import side effect)

__all__ = [
    "EventConfig",
    "EventState",
    "EventTape",
    "KIND_GRAD",
    "KIND_TX",
    "KIND_UNIFY",
    "ReplayResult",
    "STALENESS_MODES",
    "algorithms",
    "event_step",
    "events_context",
    "init_event_state",
    "profiled_event_list",
    "replay_events",
    "sample_event_tape",
    "simulate_events",
    "staleness_damping_vector",
    "staleness_fn",
    "staleness_scale",
    "tape_capacity",
    "tape_from_events",
]
