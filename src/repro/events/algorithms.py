"""The event-engine algorithm family, as registered `Algorithm` plugins.

Three continuous-timeline methods over the same `event_step` scan —

  draco-event       exact-timeline DRACO (Algorithm 2 with no window
                    discretization; the numpy `event_list` reference,
                    compiled);
  fedasync-gossip   DRACO with FedAsync staleness damping: arriving
                    weights scaled by s(delta_tau) at the exact
                    continuous message age (constant/hinge/poly
                    families, `cfg.staleness*` knobs);
  event-triggered   DRACO with Zehtabi-style broadcast suppression: a
                    transmission event only fires when the pending
                    backlog exceeds `cfg.trigger_threshold` in L2 norm
                    (`tx_sent` counts the broadcasts that actually went
                    out — the comms-savings metric);

plus one windowed hybrid, `fedasync-window`, which is plain windowed
DRACO with the staleness vector applied per delay bucket via
`core.protocol.draco_window`'s `damping=` hook — the discrete
counterpart of fedasync-gossip (with `staleness="constant"` it is
bit-for-bit "draco").

All are `simulate_sweep`-able over `lr`/`psi` (the Poisson-rate fields
shape the pre-sampled tape itself, so sweeping them inside one compiled
call is rejected — resample tapes host-side instead).
"""
from __future__ import annotations

import numpy as np

from repro.api.algorithm import register_algorithm
from repro.api.algorithms import Draco, _view
from repro.core import protocol as protocol_lib
from repro.events import engine
from repro.events.staleness import staleness_damping_vector, staleness_fn


class _EventAlgo:
    """Shared scaffolding for the tape-scanned family."""

    # lambda_grad / lambda_tx are baked into the sampled tape; only the
    # per-event knobs can be re-bound as traced scalars
    sweepable = ("lr", "psi")
    use_damping = False
    use_trigger = False

    def init(self, key, cfg, params0, task=None):
        return engine.init_event_state(key, cfg, params0, task=task)

    def step(self, state, ctx):
        cfg = ctx.cfg
        damping = staleness_fn(cfg) if self.use_damping else None
        trigger = (float(getattr(cfg, "trigger_threshold", 0.0))
                   if self.use_trigger else 0.0)
        return engine.event_step(state, ctx, damping=damping,
                                 trigger=trigger)

    def eval_params(self, state):
        return state.params

    def grads_per_step(self, cfg):
        # one tape row is one merged-process event; a fraction
        # lambda_grad / (lambda_grad + lambda_tx) of them are gradient
        # events, each owned by a single client (vs. the windowed
        # engine's per-client thinning). Rates may be per-client arrays
        # (profiled tapes) — reduce to the merged-process totals first.
        lam_g = float(np.sum(cfg.lambda_grad))
        lam = lam_g + float(np.sum(cfg.lambda_tx))
        if lam <= 0:
            return 0.0
        return lam_g / (cfg.num_clients * lam)


@register_algorithm("draco-event")
class DracoEvent(_EventAlgo):
    """Exact-timeline DRACO: the merged Poisson tape, no windows."""


@register_algorithm("fedasync-gossip")
class FedAsyncGossip(_EventAlgo):
    """Staleness-weighted event gossip: drain weights scaled by
    s(delta_tau) at the exact continuous message age."""

    use_damping = True


@register_algorithm("event-triggered")
class EventTriggered(_EventAlgo):
    """Threshold-triggered broadcasting: transmissions below the backlog
    threshold are suppressed (the backlog keeps accumulating)."""

    use_trigger = True


@register_algorithm("fedasync-window")
class FedAsyncWindow(Draco):
    """Windowed DRACO + per-bucket staleness damping (the `damping=`
    hook of `draco_window`); discrete counterpart of fedasync-gossip."""

    def step(self, state, ctx):
        v = _view(ctx, state.window_idx)
        return protocol_lib.draco_window(
            state, ctx.cfg, v.q, v.adj, ctx.task, ctx.data,
            spec=ctx.flat_spec, positions=v.positions,
            compute_rate=v.compute_rate, tx_rate=v.tx_rate,
            overrides=ctx.overrides,
            damping=staleness_damping_vector(ctx.cfg),
        )
