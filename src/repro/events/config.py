"""`EventConfig`: `DracoConfig` plus the event-family knobs.

A plain `DracoConfig` runs every event algorithm with the defaults below
(the algorithms read these fields via `getattr` with the same
fallbacks), so existing configs work unchanged; `EventConfig` makes the
knobs explicit, validated, and part of the static jit key.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.protocol import DracoConfig

STALENESS_MODES = ("constant", "hinge", "poly")


@dataclass(frozen=True)
class EventConfig(DracoConfig):
    # FedAsync-style staleness damping s(delta_tau) applied to arriving
    # message weights, delta_tau measured in superposition windows:
    #   constant: s = 1 (no damping; bit-for-bit draco-event)
    #   hinge:    s = 1 if dt <= b else 1 / (a * (dt - b) + 1)
    #   poly:     s = (dt + 1) ** (-a)
    staleness: str = "constant"
    staleness_a: float = 0.5
    staleness_b: float = 4.0
    # Event-triggered broadcast suppression (Zehtabi-style): a
    # transmission event only fires if the sender's pending backlog has
    # ||Delta||_2 >= trigger_threshold (0 = always fire).
    trigger_threshold: float = 0.0

    def __post_init__(self):
        super().__post_init__()
        if self.staleness not in STALENESS_MODES:
            raise ValueError(
                f"staleness must be one of {STALENESS_MODES}, "
                f"got {self.staleness!r}")
        if self.staleness_a <= 0:
            raise ValueError(
                f"staleness_a must be positive, got {self.staleness_a}")
        if self.staleness_b < 0:
            raise ValueError(
                f"staleness_b must be >= 0, got {self.staleness_b}")
        if self.trigger_threshold < 0:
            raise ValueError(
                "trigger_threshold must be >= 0 (0 = always fire), "
                f"got {self.trigger_threshold}")
