"""`simulate_events`: the continuous-timeline driver.

A thin front-end over the unified `repro.api.simulate` machinery: the
tape is sampled host-side (`repro.events.tape`), attached to the
`SimContext` (its `tape` slot is a traced pytree child, like the
scenario schedule), and the run is exactly `simulate(...)` with
``num_steps == tape.capacity`` — the same jitted nested scan, in-jit
metric cadence, and `simulate_sweep` axes, with `event_step` as the
per-step body. Nothing is forked: event algorithms are ordinary
registered `Algorithm`s that read `ctx.tape`.

api imports are deferred into the function bodies so this module (and
`repro.events`) can be imported before/without `repro.api` without an
import cycle — `repro.api.__init__` imports this module to re-export
`simulate_events`.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

from repro.events.tape import EventTape, sample_event_tape


def events_context(cfg, loss_fn=None, data: Any = None, *, task=None,
                   params0: Any = None, horizon: Optional[float] = None,
                   capacity: Optional[int] = None,
                   tape: Optional[EventTape] = None, tape_seed=0,
                   graph_key=None, scenario=None, scenario_key=None,
                   scenario_kwargs=None):
    """`make_context` + a sampled `EventTape` on the `tape` slot.

    `horizon` is the run length in *seconds* (the tape covers [0,
    horizon)); alternatively pass a prebuilt `tape=`. `capacity` pads
    the tape to a fixed length (`tape_capacity` rule when omitted) so
    grids of tapes share one compiled scan. When the context carries a
    scenario schedule, the tape sampling respects its straggler /
    duty-cycle rate rings (Poisson thinning; see `sample_event_tape`).
    """
    from repro.api.context import make_context

    ctx = make_context(cfg, loss_fn, data, task=task, params0=params0,
                       graph_key=graph_key, scenario=scenario,
                       scenario_key=scenario_key,
                       scenario_kwargs=scenario_kwargs)
    if tape is None:
        if horizon is None:
            raise ValueError("pass horizon= (seconds) or a prebuilt tape=")
        tape = sample_event_tape(cfg, horizon, seed=tape_seed,
                                 schedule=ctx.schedule, capacity=capacity)
    return ctx.replace(tape=tape)


def simulate_events(
    algo,
    cfg,
    params0=None,
    loss_fn: Optional[Callable] = None,
    data: Any = None,
    *,
    horizon: Optional[float] = None,
    capacity: Optional[int] = None,
    tape: Optional[EventTape] = None,
    tape_seed=0,
    task=None,
    task_key=None,
    key=None,
    eval_every: int = 0,
    eval_fn: Optional[Callable] = None,
    eval_data: Any = None,
    ctx=None,
    state: Any = None,
    graph_key=None,
    scenario=None,
    scenario_key=None,
    scenario_kwargs=None,
):
    """Run an event algorithm over one sampled timeline, jit-compiled.

    Args mirror `repro.api.simulate` with the step axis replaced by the
    timeline: `horizon` (seconds) + `tape_seed` sample the merged
    Poisson tape host-side, or pass `tape=` / a ctx from
    `events_context`. `eval_every` counts *events* (tape rows). The
    trace's `step` column is therefore an event index; convert to
    seconds via the tape's `t`.

    `algo` must be one of the event family ("draco-event",
    "fedasync-gossip", "event-triggered", or any `Algorithm` whose step
    reads `ctx.tape`). Returns `(final EventState, SimTrace)`.
    """
    from repro.api.simulate import resolve_workload, simulate

    if ctx is not None and task is None and loss_fn is None:
        # a prebuilt ctx already knows its workload; adopt it so
        # resolve_workload can build params0 for the state init (a bare
        # loss callable has no builders — pass params0 explicitly then,
        # exactly as with `simulate`)
        from repro.tasks import is_task

        if is_task(ctx.task):
            task = ctx.task
        else:
            loss_fn = ctx.task
    task, workload, params0, data, eval_data = resolve_workload(
        cfg, task, task_key, loss_fn, params0, data, eval_data,
        need_params=state is None or ctx is None, need_data=ctx is None)
    if ctx is None:
        ctx = events_context(cfg, workload, data, params0=params0,
                             horizon=horizon, capacity=capacity, tape=tape,
                             tape_seed=tape_seed, graph_key=graph_key,
                             scenario=scenario, scenario_key=scenario_key,
                             scenario_kwargs=scenario_kwargs)
    else:
        if tape is not None:
            ctx = ctx.replace(tape=tape)
        if getattr(ctx, "tape", None) is None:
            raise ValueError(
                "the prebuilt ctx carries no EventTape; build it with "
                "events_context(...) or pass tape=")
    return simulate(algo, cfg, params0=params0,
                    loss_fn=workload if task is None else None,
                    num_steps=ctx.tape.capacity, task=task, key=key,
                    eval_every=eval_every, eval_fn=eval_fn,
                    eval_data=eval_data, ctx=ctx, state=state)
