"""Sorted fixed-length event tapes: the continuous timeline, compiled.

The windowed engine discretizes the paper's merged Poisson point process
(Assumption 1) into superposition windows; the event engine keeps the
exact timeline. Host-side, each run's merged process — per-client
gradient events at rate ``lambda_grad``, transmission events at
``lambda_tx``, periodic unifications — is pre-sampled into one sorted
**event tape**: parallel ``(E,)`` arrays

    t      f32   event time (seconds, ascending)
    client i32   acting client (the rotating hub for unify events)
    kind   i32   KIND_GRAD | KIND_TX | KIND_UNIFY
    valid  bool  padding mask (False rows are strict no-ops)

padded to a fixed length exactly like the scenario `Schedule` rings are
padded to fixed periods, so one jitted scan (`repro.events.engine`)
covers every tape of the same capacity and tapes stack cleanly along
sweep axes.

Sizing rule (the ``E`` rule): the merged process has mean
``horizon * sum_i (lam_grad_i + lam_tx_i)`` events; `tape_capacity`
allocates mean + 6 sigma (Poisson variance == mean) plus the
deterministic unification count — the same 6-sigma tail bound as
`core.events.poisson_truncation_bound`, so overflow is a ~1e-9 event.
`tape_from_events` refuses to truncate silently: an overflowing sample
raises instead of biasing the timeline.

Scenario profiles: `sample_event_tape(..., schedule=...)` respects
straggler/duty-cycle rate rings by Poisson thinning — candidates are
drawn at each client's *peak* rate ``lam * max(ring)`` and kept with
probability ``rate(t) / peak``, where ``rate(t)`` reads the ring at the
window index ``floor(t / window) % T`` (piecewise-constant, exactly the
lookup the windowed engine performs via ``schedule.at``). A duty-cycled
client therefore fires no events in its off-windows.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import Event, event_list, unify_hub

KIND_GRAD = 0
KIND_TX = 1
KIND_UNIFY = 2

_KIND_CODE = {"grad": KIND_GRAD, "tx": KIND_TX, "unify": KIND_UNIFY}
KIND_NAMES = ("grad", "tx", "unify")


class EventTape(NamedTuple):
    """The pre-sampled merged timeline as fixed-length device arrays."""

    t: jax.Array  # (E,) f32, ascending over valid rows
    client: jax.Array  # (E,) i32
    kind: jax.Array  # (E,) i32 (KIND_GRAD | KIND_TX | KIND_UNIFY)
    valid: jax.Array  # (E,) bool — False rows are padding (strict no-ops)

    @property
    def capacity(self) -> int:
        return int(self.t.shape[0])

    @property
    def num_valid(self) -> int:
        """Host-side count of real (non-padding) events."""
        return int(np.asarray(self.valid).sum())

    def counts(self) -> dict:
        """Host-side events-per-kind summary (tests, benchmarks)."""
        k = np.asarray(self.kind)[np.asarray(self.valid)]
        return {name: int((k == code).sum())
                for name, code in _KIND_CODE.items()}


def tape_from_events(events: Sequence[Event],
                     capacity: Optional[int] = None) -> EventTape:
    """Pack an exact `core.events.event_list` timeline into an `EventTape`.

    The tape preserves the list's order verbatim (the list is already
    time-sorted), so the scanned engine and the numpy reference consume
    the *same* timeline by construction. `capacity` pads with masked
    rows up to a fixed length; an overflow raises rather than silently
    truncating the tail of the run.
    """
    n_ev = len(events)
    cap = n_ev if capacity is None else int(capacity)
    if n_ev > cap:
        raise ValueError(
            f"{n_ev} events exceed tape capacity {cap}; size it with "
            "tape_capacity(cfg, horizon, ...) (mean + 6 sigma)")
    t = np.zeros((cap,), np.float32)
    client = np.zeros((cap,), np.int32)
    kind = np.zeros((cap,), np.int32)
    valid = np.zeros((cap,), bool)
    for i, e in enumerate(events):
        t[i] = e.t
        client[i] = e.client
        kind[i] = _KIND_CODE[e.kind]
        valid[i] = True
    if n_ev:
        t[n_ev:] = t[n_ev - 1]  # padding keeps time monotone (cosmetic)
    return EventTape(jnp.asarray(t), jnp.asarray(client),
                     jnp.asarray(kind), jnp.asarray(valid))


def _peak_rates(cfg, schedule=None):
    """Per-client peak (lam_grad_i, lam_tx_i) after rate-ring modulation."""
    n = cfg.num_clients
    lam_g = np.broadcast_to(np.asarray(cfg.lambda_grad, np.float64), (n,))
    lam_t = np.broadcast_to(np.asarray(cfg.lambda_tx, np.float64), (n,))
    if schedule is not None:
        if schedule.compute_rate is not None:
            lam_g = lam_g * np.asarray(schedule.compute_rate).max(axis=0)
        if schedule.tx_rate is not None:
            lam_t = lam_t * np.asarray(schedule.tx_rate).max(axis=0)
    return lam_g, lam_t


def tape_capacity(cfg, horizon: float, schedule=None,
                  sigmas: float = 6.0) -> int:
    """The ``E`` sizing rule: mean merged-process count + `sigmas` std.

    Uses each client's *peak* ring-modulated rate, so profiled tapes are
    (conservatively) covered; adds the deterministic unification count.
    """
    lam_g, lam_t = _peak_rates(cfg, schedule)
    mean = float(horizon) * float(lam_g.sum() + lam_t.sum())
    cap = int(np.ceil(mean + sigmas * np.sqrt(max(mean, 1.0)))) + 1
    if cfg.unify_period > 0:
        period_s = cfg.unify_period * cfg.window
        cap += int(np.ceil(horizon / period_s))
    return cap


def _thinned_times(rng: np.random.Generator, lam: float, horizon: float,
                   ring: np.ndarray, window: float) -> List[float]:
    """Non-homogeneous Poisson times via thinning against a rate ring.

    The instantaneous rate is ``lam * ring[floor(t/window) % T]`` —
    piecewise constant per superposition window, the same lookup the
    windowed engine performs through ``schedule.at``. Candidates run at
    the peak rate; each is kept with probability rate(t)/peak.
    """
    peak = lam * float(ring.max())
    if peak <= 0:
        return []
    out: List[float] = []
    t = rng.exponential(1.0 / peak)
    while t < horizon:
        mult = float(ring[int(t // window) % len(ring)])
        if rng.uniform() < (lam * mult) / peak:
            out.append(float(t))
        t += rng.exponential(1.0 / peak)
    return out


def profiled_event_list(rng: np.random.Generator, cfg, horizon: float,
                        schedule) -> List[Event]:
    """Exact merged timeline under a scenario schedule's rate rings."""
    n = cfg.num_clients
    lam_g = np.broadcast_to(np.asarray(cfg.lambda_grad, np.float64), (n,))
    lam_t = np.broadcast_to(np.asarray(cfg.lambda_tx, np.float64), (n,))
    ones = np.ones((1, n))
    ring_g = (np.asarray(schedule.compute_rate)
              if schedule.compute_rate is not None else ones)
    ring_t = (np.asarray(schedule.tx_rate)
              if schedule.tx_rate is not None else ones)
    events: List[Event] = []
    for i in range(n):
        for lam, ring, kind in ((lam_g[i], ring_g[:, i], "grad"),
                                (lam_t[i], ring_t[:, i], "tx")):
            for t in _thinned_times(rng, float(lam), horizon, ring,
                                    cfg.window):
                events.append(Event(t, i, kind))
    if cfg.unify_period > 0:
        period_s = cfg.unify_period * cfg.window
        k = 1
        while k * period_s < horizon:
            events.append(Event(float(k * period_s), unify_hub(k, n),
                                "unify"))
            k += 1
    events.sort(key=lambda e: e.t)
    return events


def sample_event_tape(cfg, horizon: float, *, seed=0,
                      rng: Optional[np.random.Generator] = None,
                      schedule=None,
                      capacity: Optional[int] = None) -> EventTape:
    """Sample one run's merged timeline and pack it into an `EventTape`.

    Host-side numpy sampling (`seed` or an explicit `rng`), exactly the
    `core.events.event_list` process — with `schedule=`, the rate rings
    modulate it by thinning (`profiled_event_list`). `capacity` defaults
    to the `tape_capacity` sizing rule so equal-(cfg, horizon) tapes
    share one compiled scan.
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    if capacity is None:
        capacity = tape_capacity(cfg, horizon, schedule)
    if schedule is not None and (schedule.compute_rate is not None
                                 or schedule.tx_rate is not None):
        events = profiled_event_list(rng, cfg, horizon, schedule)
    else:
        events = event_list(
            rng, cfg.num_clients, horizon, cfg.lambda_grad, cfg.lambda_tx,
            unify_period=cfg.unify_period * cfg.window)
    return tape_from_events(events, capacity)
