"""Step-by-step eager replay of an event tape — the engine's oracle.

A deliberately independent re-implementation of the event semantics: a
Python loop over the tape's valid rows with eager jax ops and a plain
message *list* instead of rings — enqueue appends, the depth-D outage
bound evicts by broadcast index, and draining walks live messages in
send order with one ``w_due.T @ payload`` GEMM each. No `lax.switch`,
no `gossip_drain`, no fixed-capacity buffers.

It is nevertheless **bit-for-bit** equal to the scanned engine at f32
(tests/test_event_engine.py pins it) because both sides share the exact
contracts that determine the floats:

  - RNG: the same 4-way key split per valid event, keys consumed by the
    same sub-steps (padding rows consume nothing on either side);
  - drain order: oldest broadcast first, one f32 GEMM accumulation per
    live message, zero-weight messages skipped exactly (`gossip_drain`'s
    empty-slot `cond` contributes nothing, as does skipping the GEMM);
  - damping order: ``(w * due_mask) * s(dtau)``, the engine's
    multiplication order;
  - local updates: the same `core.protocol.local_step` call with the
    same one-hot mask.

This is the numpy-reference cross-view required by the windowed->event
parity story: `core.events.event_list` (numpy) -> `tape_from_events`
preserves the timeline verbatim, and this replay executes it one event
at a time.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as channel_lib
from repro.core import flat as flat_lib
from repro.core import protocol as protocol_lib
from repro.core.protocol import Overrides
from repro.events.tape import KIND_GRAD, KIND_TX, KIND_UNIFY


class ReplayResult(NamedTuple):
    """The replayed run's observable state (ring internals excluded —
    the replay keeps messages in a list, not a ring)."""

    params: Any
    pending: jax.Array
    opt_state: jax.Array
    accept_count: jax.Array
    total_accept: jax.Array
    tx_sent: jax.Array
    tx_count: int
    time: float
    positions: jax.Array


def replay_events(state, ctx, *, damping=None,
                  trigger: float = 0.0) -> ReplayResult:
    """Replay `ctx.tape` from an initial `EventState`, eagerly.

    Mirrors `engine.event_step` semantics with independent bookkeeping;
    `damping`/`trigger` as there. Static-config path only (`ctx.overrides`
    must be None or all-None — the oracle does not trace).
    """
    tape, cfg = ctx.tape, ctx.cfg
    n, D = cfg.num_clients, cfg.max_delay_windows
    spec = ctx.flat_spec
    if spec is None:
        spec = flat_lib.spec_of(state.params)
    ov = ctx.overrides if ctx.overrides is not None else Overrides()
    if any(f is not None for f in ov):
        raise ValueError("replay_events is the static-config oracle; "
                         "run it without traced overrides")

    params, pending, opt_state = state.params, state.pending, state.opt_state
    acc, tot, sent = state.accept_count, state.total_accept, state.tx_sent
    key, positions = state.key, state.positions
    txc = int(state.tx_count)
    t = float(state.time)
    msgs = []  # dicts: born, w (N,N), deadline (N,N), payload (N,Dflat), sent_at

    valid_np = np.asarray(tape.valid)
    kind_np = np.asarray(tape.kind)
    client_np = np.asarray(tape.client)

    for e in range(tape.capacity):
        if not valid_np[e]:
            continue
        t = tape.t[e]  # jnp f32 scalar: the same bits the scan reads
        ci = int(client_np[e])
        kind = int(kind_np[e])
        step_t = jnp.floor(t / cfg.window).astype(jnp.int32)

        if ctx.schedule is None:
            q, adj, sched_pos = ctx.q, ctx.adj, None
        else:
            v = ctx.schedule.at(step_t)
            q, adj, sched_pos = v.q, v.adj, v.positions
        pos = positions if sched_pos is None else sched_pos

        keys = jax.random.split(key, 4)
        key, k_gsel, k_chan = keys[0], keys[1], keys[2]

        # --- drain: live messages in send order, one GEMM each ------------
        arrivals = jnp.zeros((n, spec.dim), jnp.float32)
        for m in msgs:
            due = (m["deadline"] <= t).astype(m["w"].dtype)
            w_due = m["w"] * due
            if damping is not None:
                w_due = w_due * damping((t - m["sent_at"]) / cfg.window)
            if bool(jnp.any(w_due != 0)):
                arrivals = arrivals + jax.lax.dot(
                    w_due.T.astype(jnp.float32),
                    m["payload"].astype(jnp.float32))
            m["w"] = m["w"] * (m["deadline"] > t).astype(m["w"].dtype)
        params = jax.tree_util.tree_map(
            lambda p, a: p + a.astype(p.dtype), params,
            flat_lib.unravel_clients(arrivals, spec))

        # --- dispatch ------------------------------------------------------
        if kind == KIND_GRAD:
            gm = jnp.arange(n, dtype=jnp.int32) == ci
            delta, opt_state = protocol_lib.local_step(
                k_gsel, params, gm, cfg, ctx.task, ctx.data, opt_state,
                step_t, lr=None)
            pending = pending + flat_lib.ravel_clients(delta)
            if cfg.apply_self_update:
                params = jax.tree_util.tree_map(
                    lambda p, dl: p + dl.astype(p.dtype), params, delta)
        elif kind == KIND_TX:
            sender = jnp.arange(n, dtype=jnp.int32) == ci
            if cfg.channel is not None and cfg.channel.enabled:
                gamma, success = channel_lib.transmission_delays(
                    k_chan, pos, sender, cfg.channel)
                success = success & adj
                deadlines = (t + gamma).astype(jnp.float32)
            else:
                success = adj & sender[:, None]
                deadlines = jnp.full((n, n), t, jnp.float32)
            if trigger > 0:
                fire = bool(jnp.sum(pending[ci] ** 2)
                            >= jnp.float32(trigger) ** 2)
            else:
                fire = True
            psi = cfg.psi
            room = success if psi <= 0 else success & (acc[None, :] < psi)
            accept = room & fire
            newly = accept.sum(axis=0).astype(jnp.int32)
            acc = acc + newly
            tot = tot + newly
            w_eff = q * accept.astype(q.dtype)
            if fire:
                msgs.append({"born": txc, "w": w_eff, "deadline": deadlines,
                             "payload": pending, "sent_at": t})
                txc += 1
                # depth-D ring: broadcast txc-1 evicts broadcast txc-1-D
                msgs = [m for m in msgs if m["born"] >= txc - D]
                keep = ~sender
                pending = pending * keep.astype(jnp.float32)[:, None]
                sent = sent + sender.astype(jnp.int32)
        elif kind == KIND_UNIFY:
            params = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[ci][None], x.shape), params)
            acc = jnp.zeros_like(acc)
        else:  # pragma: no cover - tape kinds are validated at pack time
            raise ValueError(f"unknown event kind {kind}")
        positions = pos

    return ReplayResult(params=params, pending=pending, opt_state=opt_state,
                        accept_count=acc, total_accept=tot, tx_sent=sent,
                        tx_count=txc, time=float(np.asarray(t)),
                        positions=positions)
