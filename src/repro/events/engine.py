"""The scanned continuous-time event engine.

One `event_step` consumes one row of the context's `EventTape` inside a
jitted scan: the state's event cursor reads ``(t, client, kind, valid)``
and dispatches through `lax.switch` onto the three handlers —

  KIND_GRAD   B local batches for the acting client through the Task
              optimizer plane (`core.protocol.local_step` with a one-hot
              grad mask), accumulated into the pending backlog;
  KIND_TX     the acting client broadcasts its pending backlog through
              the (optional) wireless channel into the payload ring,
              subject to event-triggered suppression and the Psi cap;
  KIND_UNIFY  the tape's precomputed rotating hub broadcasts its model.

Before the dispatch, every event **drains**: ring messages whose
continuous delivery deadline ``t_send + gamma_link`` has passed are
mixed into the receivers via the fused `gossip_ops.gossip_drain`
(Pallas on TPU, unrolled GEMM + empty-slot skipping elsewhere) — the
same kernel the windowed engine drains with, reused, not forked. The
ring is deadline-stamped rather than age-bucketed: `w_ring` holds the
undelivered effective weights, `deadline_ring` the per-link absolute
delivery times, and draining zeroes exactly the delivered entries, so a
message's per-link copies can arrive at different events.

Ring semantics: broadcast ``b`` lives in slot ``b % D``; enqueueing
broadcast ``b`` evicts broadcast ``b - D`` (drop-on-overwrite — the
depth-D ring is the same outage bound as the windowed engine's
`quantize_delays` drop). Draining walks the D slots oldest-broadcast
first, so the f32 accumulation order is deterministic and matches the
step-by-step reference `repro.events.replay` bit-for-bit.

With the channel disabled, deadlines equal the send time and messages
arrive at the next strictly-later event — the window->0 limit of the
windowed engine's one-window delay.

Padding rows (``valid == False``) are strict no-ops: the whole proposed
state (RNG key and clocks included) is discarded via a scalar select,
so a padded tape equals its unpadded prefix bit-for-bit.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel as channel_lib
from repro.core import flat as flat_lib
from repro.core import protocol as protocol_lib
from repro.core.channel import ChannelConfig
from repro.core.protocol import Overrides
from repro.kernels.gossip import ops as gossip_ops


class EventState(NamedTuple):
    params: Any  # pytree, leaves (N, ...)
    pending: jax.Array  # (N, Dflat) f32 — untransmitted backlog (Lemma A.1)
    buffer: jax.Array  # (D, N, Dflat) f32 — raw broadcast payload ring
    w_ring: jax.Array  # (D, N, N) f32 — undelivered effective weights
    deadline_ring: jax.Array  # (D, N, N) f32 — absolute delivery times (s)
    send_time: jax.Array  # (D,) f32 — slot send timestamps (staleness)
    accept_count: jax.Array  # (N,) msgs accepted this unification period
    total_accept: jax.Array  # (N,) msgs accepted over the whole run
    tx_sent: jax.Array  # (N,) broadcasts actually fired (post-suppression)
    tx_count: jax.Array  # scalar i32 — broadcast counter / slot allocator
    event_idx: jax.Array  # scalar i32 — tape cursor
    time: jax.Array  # scalar f32 — last processed event time
    key: jax.Array
    positions: jax.Array  # (N, 2) node coordinates (channel model)
    opt_state: jax.Array = ()  # (N, Dopt) f32 — flat local optimizer plane


def init_event_state(key, cfg, params0, task=None) -> EventState:
    """Replicate `params0` across N clients; empty rings and counters.

    Same (placement, state) key derivation as `protocol.init_state`, so
    an event run and a windowed run started from the same key see the
    same node positions.
    """
    n, d = cfg.num_clients, cfg.max_delay_windows
    kp, ks = jax.random.split(key)
    params = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape).copy(), params0
    )
    spec = flat_lib.spec_of(params)
    pos = channel_lib.place_nodes(kp, n, cfg.channel or ChannelConfig())
    return EventState(
        params=params,
        pending=jnp.zeros((n, spec.dim), jnp.float32),
        buffer=jnp.zeros((d, n, spec.dim), jnp.float32),
        w_ring=jnp.zeros((d, n, n), jnp.float32),
        deadline_ring=jnp.zeros((d, n, n), jnp.float32),
        send_time=jnp.zeros((d,), jnp.float32),
        accept_count=jnp.zeros((n,), jnp.int32),
        total_accept=jnp.zeros((n,), jnp.int32),
        tx_sent=jnp.zeros((n,), jnp.int32),
        tx_count=jnp.zeros((), jnp.int32),
        event_idx=jnp.zeros((), jnp.int32),
        time=jnp.zeros((), jnp.float32),
        key=ks,
        positions=pos,
        opt_state=protocol_lib._opt_plane(task, params0, n),
    )


def event_step(state: EventState, ctx, *, damping=None,
               trigger: float = 0.0) -> EventState:
    """One tape row: drain due messages, then dispatch on the event kind.

    `ctx` is a `SimContext` carrying an `EventTape` (see
    `repro.events.driver.events_context`). `damping` is the staleness
    closure (None = undamped DRACO semantics, bit-for-bit); `trigger` is
    the static event-triggered suppression threshold (0 = always fire).
    Scenario schedules are honored at the *protocol* clock: the step-t
    snapshot is ``ctx.schedule.at(floor(t / window))``, the same ring
    lookup as the windowed engine.
    """
    tape = ctx.tape
    if tape is None:
        raise ValueError(
            "event algorithms need a ctx carrying an EventTape; build one "
            "with repro.events.events_context(...) or call simulate_events")
    cfg = ctx.cfg
    n, D = cfg.num_clients, cfg.max_delay_windows
    spec = ctx.flat_spec
    if spec is None:
        spec = flat_lib.spec_of(state.params)
    ov = ctx.overrides if ctx.overrides is not None else Overrides()

    e = state.event_idx
    t = tape.t[e]
    ci = tape.client[e]
    kind = tape.kind[e]
    valid = tape.valid[e]
    step_t = jnp.floor(t / cfg.window).astype(jnp.int32)

    if ctx.schedule is None:
        q, adj, sched_pos = ctx.q, ctx.adj, None
    else:
        v = ctx.schedule.at(step_t)
        q, adj, sched_pos = v.q, v.adj, v.positions
    pos = state.positions if sched_pos is None else sched_pos

    keys = jax.random.split(state.key, 4)
    k_next, k_gsel, k_chan, _ = keys

    # --- 1. continuous-time drain: everything due by t ---------------------
    slots = jnp.mod(state.tx_count + jnp.arange(D, dtype=jnp.int32), D)
    due = state.deadline_ring <= t  # (D, N, N)
    w_live = state.w_ring * due.astype(state.w_ring.dtype)
    w_stack = w_live[slots]
    if damping is not None:
        dtau = (t - state.send_time[slots]) / cfg.window
        w_stack = w_stack * damping(dtau)[:, None, None]
    arrivals_flat = gossip_ops.gossip_drain(w_stack, state.buffer, slots)
    arrivals = flat_lib.unravel_clients(arrivals_flat, spec)
    params = jax.tree_util.tree_map(
        lambda p, a: p + a.astype(p.dtype), state.params, arrivals
    )
    w_ring = state.w_ring * (~due).astype(state.w_ring.dtype)

    carry = (params, state.pending, state.opt_state, w_ring,
             state.deadline_ring, state.buffer, state.send_time,
             state.accept_count, state.total_accept, state.tx_sent,
             state.tx_count)

    # --- 2. dispatch on the event kind -------------------------------------
    def grad_branch(c):
        (params, pending, opt_state, w_ring, dl_ring, buffer, send_time,
         acc, tot, sent, txc) = c
        gm = jnp.arange(n, dtype=jnp.int32) == ci
        delta, opt_state = protocol_lib.local_step(
            k_gsel, params, gm, cfg, ctx.task, ctx.data, opt_state, step_t,
            lr=ov.lr)
        pending = pending + flat_lib.ravel_clients(delta)
        if cfg.apply_self_update:
            params = jax.tree_util.tree_map(
                lambda p, dl: p + dl.astype(p.dtype), params, delta)
        return (params, pending, opt_state, w_ring, dl_ring, buffer,
                send_time, acc, tot, sent, txc)

    def tx_branch(c):
        (params, pending, opt_state, w_ring, dl_ring, buffer, send_time,
         acc, tot, sent, txc) = c
        sender = jnp.arange(n, dtype=jnp.int32) == ci
        if cfg.channel is not None and cfg.channel.enabled:
            gamma, success = channel_lib.transmission_delays(
                k_chan, pos, sender, cfg.channel)
            success = success & adj
            deadlines = (t + gamma).astype(jnp.float32)
        else:
            # gamma = 0: due at the next strictly-later event (window->0
            # limit of the windowed engine's one-window delay)
            success = adj & sender[:, None]
            deadlines = jnp.full((n, n), t, jnp.float32)
        if trigger > 0:
            fire = jnp.sum(pending[ci] ** 2) >= jnp.float32(trigger) ** 2
        else:
            fire = jnp.asarray(True)
        # Psi cap: a single sender needs no priority permutation — the
        # receiver either has room this period or it does not
        psi = cfg.psi if ov.psi is None else ov.psi
        if isinstance(psi, (int, np.integer)):
            room = success if psi <= 0 else success & (acc[None, :] < psi)
        else:
            psi_eff = jnp.where(psi <= 0, jnp.iinfo(jnp.int32).max // 2,
                                psi.astype(jnp.int32))
            room = success & (acc[None, :] < psi_eff)
        accept = room & fire
        newly = accept.sum(axis=0).astype(jnp.int32)
        acc = acc + newly
        tot = tot + newly
        w_eff = q * accept.astype(q.dtype)

        slot = jnp.mod(txc, D)  # enqueue evicts broadcast txc - D
        buffer = jnp.where(
            fire,
            jax.lax.dynamic_update_slice(buffer, pending[None], (slot, 0, 0)),
            buffer)
        w_ring = jnp.where(fire, w_ring.at[slot].set(w_eff), w_ring)
        dl_ring = jnp.where(fire, dl_ring.at[slot].set(deadlines), dl_ring)
        send_time = jnp.where(fire, send_time.at[slot].set(t), send_time)
        sent = sent + (sender & fire).astype(jnp.int32)
        txc = txc + fire.astype(jnp.int32)
        keep = ~(sender & fire)  # suppressed senders keep their backlog
        pending = pending * keep.astype(jnp.float32)[:, None]
        return (params, pending, opt_state, w_ring, dl_ring, buffer,
                send_time, acc, tot, sent, txc)

    def unify_branch(c):
        (params, pending, opt_state, w_ring, dl_ring, buffer, send_time,
         acc, tot, sent, txc) = c
        # hub = tape.client (precomputed rotating hub, `unify_hub`)
        params = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[ci][None], x.shape), params)
        acc = jnp.zeros_like(acc)
        return (params, pending, opt_state, w_ring, dl_ring, buffer,
                send_time, acc, tot, sent, txc)

    out = jax.lax.switch(kind, (grad_branch, tx_branch, unify_branch), carry)
    (params, pending, opt_state, w_ring, dl_ring, buffer, send_time,
     acc, tot, sent, txc) = out
    new_state = EventState(
        params=params, pending=pending, buffer=buffer, w_ring=w_ring,
        deadline_ring=dl_ring, send_time=send_time, accept_count=acc,
        total_accept=tot, tx_sent=sent, tx_count=txc, event_idx=e, time=t,
        key=k_next, positions=pos, opt_state=opt_state)
    # padding rows discard everything (key and clocks included), so a
    # padded tape equals its unpadded prefix bit-for-bit
    state = jax.tree_util.tree_map(
        lambda nw, old: jnp.where(valid, nw, old), new_state, state)
    return state._replace(event_idx=e + 1)
