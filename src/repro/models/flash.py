"""Pure-JAX FlashAttention-2 with a custom VJP.

The scan-based online-softmax forward alone does NOT save training
memory: scan linearization stores every per-step carry (the (B,H,bq,hd)
accumulator), which for wide-head models is as large as the score matrix
(measured on yi-34b: no temp reduction). The fix is the FlashAttention-2
factorization — save only (out, logsumexp) per q block and *recompute*
the block probabilities in the backward pass:

  fwd:  out_i, lse_i = online-softmax over kv blocks j <= i
  bwd:  D_i = rowsum(dout_i * out_i)
        p_ij = exp(q_i k_j^T / sqrt(d) - lse_i)
        dv_j += p_ij^T dout_i ;  dp = p o (dout_i v_j^T - D_i)
        dq_i += dp k_j ;         dk_j += dp^T q_i

Residual memory: q,k,v + out + (B,H,S) stats — O(S), never O(S^2).
This is exactly what a Pallas/TPU flash kernel does; expressed here in
lax.scan form so the XLA dry-run measures its memory behaviour.

Layout: q (B,H,S,hd), k/v (B,H,T,hd) (kv heads already repeated or
grouped by the caller). Causal + optional sliding window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(qi, kj, bq, bk, window):
    iq = qi * bq + jnp.arange(bq)[:, None]
    jk = kj * bk + jnp.arange(bk)[None, :]
    m = jk <= iq
    if window > 0:
        m = m & (jk > iq - window)
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, block_q: int = 512, block_kv: int = 512,
                    sliding_window: int = 0):
    out, _ = _flash_fwd_impl(q, k, v, block_q, block_kv, sliding_window)
    return out


def _flash_fwd_impl(q, k, v, bq, bk, window):
    B, H, S, hd = q.shape
    T = k.shape[2]
    nq, nk = S // bq, T // bk
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qb = q.reshape(B, H, nq, bq, hd)
    kb = k.reshape(B, H, nk, bk, hd)
    vb = v.reshape(B, H, nk, bk, hd)

    def q_block(qi, q_i):
        def kv_step(carry, inp):
            acc, m, l = carry
            kj, k_j, v_j = inp
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_j).astype(jnp.float32) * scale
            s = jnp.where(_mask(qi, kj, bq, bk, window)[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_j.dtype), v_j).astype(jnp.float32)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, H, bq, hd), jnp.float32)
        m0 = jnp.full((B, H, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, bq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.arange(nk), jnp.swapaxes(kb, 0, 2).swapaxes(1, 2),
             jnp.swapaxes(vb, 0, 2).swapaxes(1, 2)))
        l_safe = jnp.maximum(l, 1e-30)
        out_i = (acc / l_safe[..., None]).astype(q.dtype)
        lse_i = m + jnp.log(l_safe)
        return out_i, lse_i

    outs, lses = jax.lax.map(
        lambda args: q_block(*args),
        (jnp.arange(nq), jnp.swapaxes(qb, 0, 2).swapaxes(1, 2)))
    out = jnp.swapaxes(jnp.swapaxes(outs, 1, 2), 0, 2).reshape(B, H, S, hd)
    lse = jnp.swapaxes(jnp.swapaxes(lses, 1, 2), 0, 2).reshape(B, H, S)
    return out, lse


def _flash_fwd(q, k, v, bq, bk, window):
    out, lse = _flash_fwd_impl(q, k, v, bq, bk, window)
    return out, (q, k, v, out, lse)


def _flash_bwd(bq, bk, window, res, dout):
    q, k, v, out, lse = res
    B, H, S, hd = q.shape
    T = k.shape[2]
    nq, nk = S // bq, T // bk
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    D = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)  # (B,H,S)

    qb = q.reshape(B, H, nq, bq, hd)
    doutb = dout.reshape(B, H, nq, bq, hd)
    lseb = lse.reshape(B, H, nq, bq)
    Db = D.reshape(B, H, nq, bq)
    kb = k.reshape(B, H, nk, bk, hd)
    vb = v.reshape(B, H, nk, bk, hd)

    def q_block(carry, inp):
        dk_acc, dv_acc = carry
        qi, q_i, dout_i, lse_i, D_i = inp

        def kv_step(dq_i, inp2):
            kj, k_j, v_j = inp2
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_j).astype(jnp.float32) * scale
            s = jnp.where(_mask(qi, kj, bq, bk, window)[None, None], s, NEG_INF)
            p = jnp.exp(s - lse_i[..., None])  # recomputed, never saved
            dp = jnp.einsum("bhqd,bhkd->bhqk", dout_i.astype(jnp.float32),
                            v_j.astype(jnp.float32))
            ds = p * (dp - D_i[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhqk,bhkd->bhqd", ds, k_j.astype(jnp.float32))
            dk_j = jnp.einsum("bhqk,bhqd->bhkd", ds, q_i.astype(jnp.float32))
            dv_j = jnp.einsum("bhqk,bhqd->bhkd", p, dout_i.astype(jnp.float32))
            return dq_i, (dk_j, dv_j)

        dq0 = jnp.zeros((B, H, bq, hd), jnp.float32)
        dq_i, (dks, dvs) = jax.lax.scan(
            kv_step, dq0,
            (jnp.arange(nk), jnp.swapaxes(kb, 0, 2).swapaxes(1, 2),
             jnp.swapaxes(vb, 0, 2).swapaxes(1, 2)))
        # dks: (nk, B, H, bk, hd) contributions from this q block
        return (dk_acc + dks, dv_acc + dvs), dq_i

    dk0 = jnp.zeros((nk, B, H, bk, hd), jnp.float32)
    dv0 = jnp.zeros((nk, B, H, bk, hd), jnp.float32)
    (dk_acc, dv_acc), dqs = jax.lax.scan(
        q_block, (dk0, dv0),
        (jnp.arange(nq), jnp.swapaxes(qb, 0, 2).swapaxes(1, 2),
         jnp.swapaxes(doutb, 0, 2).swapaxes(1, 2),
         jnp.swapaxes(lseb, 0, 2).swapaxes(1, 2),
         jnp.swapaxes(Db, 0, 2).swapaxes(1, 2)))
    dq = jnp.swapaxes(jnp.swapaxes(dqs, 1, 2), 0, 2).reshape(B, H, S, hd)
    dk = jnp.swapaxes(jnp.swapaxes(dk_acc, 1, 2), 0, 2).reshape(B, H, T, hd)
    dv = jnp.swapaxes(jnp.swapaxes(dv_acc, 1, 2), 0, 2).reshape(B, H, T, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
