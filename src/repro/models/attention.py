"""GQA attention: full / blocked(online-softmax) / sliding-window / decode.

Shapes convention: activations (B, S, d); heads materialized as
(B, S, H, hd). KV caches:

  - full cache:   k/v (B, S_max, Hkv, hd) + write position
  - ring cache:   k/v (B, W, Hkv, hd), W = sliding window; slot = pos % W
    (sub-quadratic, O(W) memory — used for dense archs at long_500k)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init
from repro.sharding.axes import constrain

NEG_INF = -1e30


def init_attention(key, cfg, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    p = {
        "wq": dense_init(kq, (d, nq * hd), d, dtype),
        "wk": dense_init(kk, (d, nkv * hd), d, dtype),
        "wv": dense_init(kv, (d, nkv * hd), d, dtype),
        "wo": dense_init(ko, (nq * hd, d), nq * hd, dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


def _split_heads(x, n_heads, hd):
    return x.reshape(*x.shape[:-1], n_heads, hd)


def _proj_qkv(params, x, kv_x, cfg):
    hd = cfg.resolved_head_dim
    q = x @ params["wq"]
    k = kv_x @ params["wk"]
    v = kv_x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return (
        _split_heads(q, cfg.num_heads, hd),
        _split_heads(k, cfg.num_kv_heads, hd),
        _split_heads(v, cfg.num_kv_heads, hd),
    )


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=-2)


def _sdpa(q, k, v, mask):
    """q (B,S,Hq,hd), k/v (B,T,Hq,hd); mask broadcastable (B,1,S,T)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _sdpa_grouped(q, k, v, mask, n_rep: int):
    """GQA attention WITHOUT materializing the repeated K/V.

    q (B,S,Hq,hd) with Hq = Hkv*n_rep; k/v (B,T,Hkv,hd) stay at kv-head
    width (the 7x repeat of a 32k cache was a measured memory/collective
    hot-spot at decode). mask broadcastable against (B,g,r,S,T)."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    qg = q.reshape(B, S, Hkv, n_rep, hd)
    scores = jnp.einsum("bsgrd,btgd->bgrst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd)
    scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgrst,btgd->bsgrd", probs, v)
    return out.reshape(B, S, Hq, hd)


def full_attention(params, x, cfg, positions=None, kv_x=None, cross=False,
                   sliding_window: int = 0):
    """Causal (or cross) attention, scores fully materialized."""
    B, S, _ = x.shape
    kv_src = kv_x if kv_x is not None else x
    q, k, v = _proj_qkv(params, x, kv_src, cfg)
    if not cross:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    T = k.shape[1]
    if cross:
        mask = jnp.ones((1, 1, S, T), bool)
    else:
        i = jnp.arange(S)[:, None]
        j = jnp.arange(T)[None, :]
        mask = j <= i
        if sliding_window > 0:
            mask = mask & (j > i - sliding_window)
        mask = mask[None, None]
    q = constrain(q, "batch", None, "heads", None)
    out = _sdpa_grouped(q, k, v, mask, n_rep)
    out = out.reshape(B, S, -1)
    return out @ params["wo"]


def blocked_attention(params, x, cfg, block_q: int = 512, block_kv: int = 1024,
                      sliding_window: int = 0, remat_steps: bool = True):
    """Causal self-attention with online softmax over KV blocks.

    O(S * block) score memory, flash-style: scan over kv blocks per q
    block. ``remat_steps`` wraps each kv step in jax.checkpoint so the
    backward pass recomputes the per-block probabilities instead of
    saving them (without it, scan residuals reconstitute the full S x S
    score matrix and the memory win disappears — measured).
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = _proj_qkv(params, x, x, cfg)
    positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    H = cfg.num_heads

    n_q = S // block_q
    n_kv = S // block_kv
    qb = q.reshape(B, n_q, block_q, H, hd)
    kb = k.reshape(B, n_kv, block_kv, H, hd)
    vb = v.reshape(B, n_kv, block_kv, H, hd)

    def q_block(qi, q_i):
        q_start = qi * block_q

        def kv_step(carry, inputs):
            acc, m, l = carry
            kv_i, k_j, v_j = inputs
            kv_start = kv_i * block_kv
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j).astype(jnp.float32)
            s = s / jnp.sqrt(hd)
            iq = q_start + jnp.arange(block_q)[:, None]
            jk = kv_start + jnp.arange(block_kv)[None, :]
            msk = jk <= iq
            if sliding_window > 0:
                msk = msk & (jk > iq - sliding_window)
            s = jnp.where(msk[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_j.dtype), v_j
            ).astype(jnp.float32)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, H, block_q, hd), jnp.float32)
        m0 = jnp.full((B, H, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        kv_idx = jnp.arange(n_kv)
        step = jax.checkpoint(kv_step) if remat_steps else kv_step
        (acc, m, l), _ = jax.lax.scan(
            step, (acc0, m0, l0), (kv_idx, jnp.swapaxes(kb, 0, 1), jnp.swapaxes(vb, 0, 1))
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.einsum("bhqd->bqhd", out).astype(x.dtype)

    outs = jax.lax.map(lambda args: q_block(*args), (jnp.arange(n_q), jnp.swapaxes(qb, 0, 1)))
    out = jnp.swapaxes(outs, 0, 1).reshape(B, S, H * hd)
    return out @ params["wo"]


def flash_self_attention(params, x, cfg, sliding_window: int = 0,
                         block_q: int = 512, block_kv: int = 512):
    """Causal self-attention via the custom-VJP FlashAttention-2 path
    (O(S) residual memory — the trainable long-sequence path)."""
    from repro.models.flash import flash_attention

    B, S, _ = x.shape
    q, k, v = _proj_qkv(params, x, x, cfg)
    positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    qT = jnp.swapaxes(q, 1, 2)  # (B,H,S,hd)
    kT = jnp.swapaxes(k, 1, 2)
    vT = jnp.swapaxes(v, 1, 2)
    bq = min(block_q, S)
    bk = min(block_kv, S)
    out = flash_attention(qT, kT, vT, bq, bk, sliding_window)
    out = jnp.swapaxes(out, 1, 2).reshape(B, S, -1)
    return out @ params["wo"]


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array  # (B, C, Hkv, hd)
    v: jax.Array
    # ring=True -> C == sliding window, slot = pos % C

    @staticmethod
    def init(batch, cache_len, n_kv, hd, dtype):
        z = jnp.zeros((batch, cache_len, n_kv, hd), dtype)
        return KVCache(z, z)


def decode_attention(params, x, cache: KVCache, pos, cfg, ring: bool = False):
    """One-token decode. x (B,1,d); pos scalar int (current position).

    Returns (out (B,1,d), new_cache). With ``ring=True`` the cache is a
    ring buffer of length W (sliding-window attention, O(W) per token).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q, k, v = _proj_qkv(params, x, x, cfg)
    pos_arr = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, pos_arr, cfg.rope_theta)
    k = apply_rope(k, pos_arr, cfg.rope_theta)

    C = cache.k.shape[1]
    slot = jnp.mod(pos, C) if ring else jnp.minimum(pos, C - 1)
    new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, axis=1)

    n_rep = cfg.num_heads // cfg.num_kv_heads
    kk = constrain(new_k, "batch", "cache_seq", "kv_heads", None)
    vv = constrain(new_v, "batch", "cache_seq", "kv_heads", None)

    idx = jnp.arange(C)
    if ring:
        valid = (idx <= slot) | (pos >= C)  # full ring once wrapped
    else:
        valid = idx <= pos
    mask = valid[None, None, None, :]
    out = _sdpa_grouped(q, kk, vv, mask, n_rep)
    out = out.reshape(B, 1, -1) @ params["wo"]
    return out, KVCache(new_k, new_v)


def cross_decode_attention(params, x, k_cache, v_cache, cfg):
    """Cross-attn at decode: static precomputed K/V over patch tokens."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q = _split_heads(x @ params["wq"], cfg.num_heads, hd)
    n_rep = cfg.num_heads // cfg.num_kv_heads
    kk, vv = _repeat_kv(k_cache, n_rep), _repeat_kv(v_cache, n_rep)
    mask = jnp.ones((1, 1, 1, kk.shape[1]), bool)
    out = _sdpa(q, kk, vv, mask)
    return out.reshape(B, 1, -1) @ params["wo"]
