"""Shared layer primitives: init, RMSNorm, RoPE, SwiGLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_dim=None, dtype=jnp.float32):
    in_dim = in_dim if in_dim is not None else shape[0]
    scale = 1.0 / jnp.sqrt(jnp.maximum(in_dim, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_mlp(key, d_model: int, d_ff: int, dtype):
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(kg, (d_model, d_ff), d_model, dtype),
        "w_up": dense_init(ku, (d_model, d_ff), d_model, dtype),
        "w_down": dense_init(kd, (d_ff, d_model), d_ff, dtype),
    }


def mlp(params, x, constrain_fn=None):
    """SwiGLU MLP. x: (..., d)."""
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    if constrain_fn is not None:
        h = constrain_fn(h)
    return h @ params["w_down"]


def cross_entropy(logits, labels, mask=None):
    """Mean token-level CE. logits (..., V) f32-safe; labels (...,) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
