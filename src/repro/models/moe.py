"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

TPU-native design notes (vs. the common GPU scatter/gather CUDA path):
  - static shapes throughout: tokens are ranked within their expert queue
    via an argsort (stable, O(T k log)), clipped to a per-expert capacity
    C = ceil(cf * k * T / E) — dropped tokens pass through the residual.
  - expert compute is one batched einsum over stacked expert weights
    (E, d, f): with the expert axis sharded over the "model" mesh axis
    this lowers to expert-parallel all-to-all style collectives.
  - the (E, C, d) dispatch buffer is sharding-constrained on the expert
    axis so each model shard only materializes its own experts' queues.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding.axes import constrain


def init_moe(key, cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "router": dense_init(kr, (d, E), d, jnp.float32),
        "experts_gate": dense_init(kg, (E, d, f), d, dtype),
        "experts_up": dense_init(ku, (E, d, f), d, dtype),
        "experts_down": dense_init(kd, (E, f, d), f, dtype),
    }


def _capacity(T: int, cfg) -> int:
    c = int(cfg.capacity_factor * cfg.experts_per_token * T / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def moe_block(params, x, cfg):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32)) @ params["router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss.
    density = jnp.zeros((E,), jnp.float32).at[eidx.reshape(-1)].add(1.0) / (T * k)
    p_mean = probs.mean(0)
    aux = E * jnp.sum(density * p_mean) * cfg.router_aux_weight

    # ---- rank each (token, slot) within its expert queue ----------------
    flat_e = eidx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(T * k) - starts[sorted_e]
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))

    C = _capacity(T, cfg)
    keep = rank < C
    dst = jnp.where(keep, flat_e * C + rank, E * C)  # E*C = drop bin

    # ---- dispatch: (E*C+1, d) buffer, expert axis sharded ---------------
    src_tok = jnp.arange(T * k) // k
    rows = xt[src_tok] * keep[:, None].astype(xt.dtype)
    buf = jnp.zeros((E * C + 1, d), xt.dtype).at[dst].add(rows)
    buf = buf[: E * C].reshape(E, C, d)
    buf = constrain(buf, "experts", None, None)

    # ---- expert FFN (batched over experts) -------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["experts_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["experts_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, params["experts_down"])
    out_e = constrain(out_e, "experts", None, None)

    # ---- combine ---------------------------------------------------------
    out_rows = out_e.reshape(E * C, d)
    out_rows = jnp.concatenate([out_rows, jnp.zeros((1, d), out_rows.dtype)], 0)
    gathered = out_rows[dst]  # (T*k, d); drop bin -> zeros row
    gathered = gathered * (gate.reshape(-1, 1).astype(gathered.dtype))
    out = gathered.reshape(T, k, d).sum(1)
    return out.reshape(B, S, d), aux
