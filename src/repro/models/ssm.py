"""Mamba2 / SSD block (state-space duality, arXiv:2405.21060).

TPU adaptation: the SSD *chunked* form is used — intra-chunk work is an
MXU-friendly (Q x Q) masked matmul per head (chunk Q = 128, lane-aligned),
inter-chunk state is carried by an associative scan over chunks. The
intra-chunk hot loop also exists as a Pallas kernel
(``repro.kernels.ssd``) validated against ``ssd_reference`` here.

Layer structure (Mamba2):
  in_proj -> [z | xBC | dt]; causal depthwise conv over xBC; SSD;
  gated RMSNorm(y * silu(z)); out_proj.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm
from repro.sharding.axes import constrain


def init_ssm(key, cfg):
    d = cfg.d_model
    di, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    H = cfg.ssm_heads
    conv_ch = di + 2 * G * N
    k1, k2, k3 = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.dtype)
    d_in_proj = 2 * di + 2 * G * N + H
    return {
        "in_proj": dense_init(k1, (d, d_in_proj), d, dtype),
        "conv_w": dense_init(k2, (conv_ch, cfg.ssm_conv_width), cfg.ssm_conv_width, dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "ssm_d": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gnorm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(k3, (di, d), di, dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B,T,ch), w (ch,W)."""
    W = w.shape[-1]
    pads = [jnp.pad(x, ((0, 0), (W - 1 - i, i), (0, 0)))[:, : x.shape[1]] for i in range(W)]
    # pads[i] is x shifted so that position t sees x[t - (W-1-i)]
    out = sum(p * w[None, None, :, i] for i, p in enumerate(pads))
    return jax.nn.silu(out + b[None, None, :])


def _split_proj(proj, cfg):
    di, N, G, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    z, xBC, dt = jnp.split(proj, [di, 2 * di + 2 * G * N], axis=-1)
    return z, xBC, dt


def _split_xbc(xBC, cfg):
    di, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    x, B_, C_ = jnp.split(xBC, [di, di + G * N], axis=-1)
    return x, B_, C_


def ssd_reference(x, dt, A, B_, C_, D, chunk: int = 0):
    """Naive sequential SSD recurrence — the oracle.

    x (B,T,H,P); dt (B,T,H); A (H,); B_/C_ (B,T,G,N); D (H,).
    h_t = exp(dt A) h_{t-1} + dt B_t (x) ; y_t = C_t h_t + D x_t.
    """
    Bb, T, H, P = x.shape
    G = B_.shape[2]
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=2)  # (B,T,H,N)
    Ch = jnp.repeat(C_, rep, axis=2)
    a = jnp.exp(dt * A[None, None, :])  # (B,T,H)

    def step2(h, inp):
        a_t, dt_t, B_t, C_t, x_t = inp  # (B,H) (B,H) (B,H,N) (B,H,N) (B,H,P)
        h = h * a_t[..., None, None] + jnp.einsum("bhn,bhp->bhnp", B_t * dt_t[..., None], x_t)
        y = jnp.einsum("bhn,bhnp->bhp", C_t, h)
        return h, y

    h0 = jnp.zeros((Bb, H, B_.shape[-1], P), jnp.float32)
    xs = (
        jnp.moveaxis(a, 1, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Bh, 1, 0).astype(jnp.float32),
        jnp.moveaxis(Ch, 1, 0).astype(jnp.float32),
        jnp.moveaxis(x, 1, 0).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step2, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,T,H,P)
    return (y + x.astype(jnp.float32) * D[None, None, :, None]).astype(x.dtype)


def ssd_chunked(x, dt, A, B_, C_, D, chunk: int):
    """Chunked SSD (parallel form). Same signature/semantics as the oracle."""
    Bb, T, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Q = chunk
    assert T % Q == 0, (T, Q)
    nc = T // Q

    f32 = jnp.float32
    xc = x.reshape(Bb, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(Bb, nc, Q, H).astype(f32)
    Bc = B_.reshape(Bb, nc, Q, G, N).astype(f32)
    Cc = C_.reshape(Bb, nc, Q, G, N).astype(f32)

    la = dtc * A[None, None, None, :]  # (B,nc,Q,H) log-decay
    cums = jnp.cumsum(la, axis=2)  # inclusive

    # --- intra-chunk: Y = (L o (C B^T) o dt_j) X --------------------------
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)  # (B,nc,G,Qi,Qj)
    CB = jnp.repeat(CB, rep, axis=2)  # (B,nc,H,Qi,Qj)
    # L[i,j] = exp(cums_i - cums_j) for i >= j else 0. Mask BEFORE exp:
    # the masked-out upper triangle has positive exponents that overflow
    # and poison gradients through jnp.where.
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -1e30))
    scores = CB * jnp.moveaxis(L, -1, 2)  # (B,nc,H,Qi,Qj)
    scores = scores * jnp.moveaxis(dtc, -1, 2)[:, :, :, None, :]  # dt_j on j axis
    Y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc)

    # --- chunk states ------------------------------------------------------
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)  # (B,nc,Q,H)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B,nc,Q,H,N)
    S = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", decay_to_end * dtc, Bh, xc)
    chunk_decay = jnp.exp(cums[:, :, -1, :])  # (B,nc,H)

    # --- inter-chunk associative scan --------------------------------------
    def combine(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s1 * d2[..., None, None] + s2

    dec, states = jax.lax.associative_scan(combine, (chunk_decay, S), axis=1)
    # state BEFORE chunk c:
    h_prev = jnp.concatenate([jnp.zeros_like(states[:, :1]), states[:, :-1]], axis=1)

    Ch = jnp.repeat(Cc, rep, axis=3)  # (B,nc,Q,H,N)
    Y_inter = jnp.einsum(
        "bcqh,bcqhn,bchnp->bcqhp", jnp.exp(cums), Ch, h_prev
    )

    y = (Y_intra + Y_inter).reshape(Bb, T, H, P)
    y = y + x.astype(f32) * D[None, None, :, None]
    return y.astype(x.dtype)


def ssm_block(params, x, cfg):
    """Full Mamba2 block forward. x (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    proj = x @ params["in_proj"]
    z, xBC, dt_raw = _split_proj(proj, cfg)
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"])
    xs, B_, C_ = _split_xbc(xBC, cfg)
    xs = xs.reshape(B, S, H, P)
    xs = constrain(xs, "batch", "seq", "ssm_heads", None)
    B_ = B_.reshape(B, S, cfg.ssm_groups, cfg.ssm_state)
    C_ = C_.reshape(B, S, cfg.ssm_groups, cfg.ssm_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    chunk = min(cfg.ssm_chunk, S)
    y = ssd_chunked(xs, dt, A, B_, C_, params["ssm_d"], chunk)
    y = y.reshape(B, S, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["gnorm"], cfg.norm_eps)
    return y @ params["out_proj"]


# ---------------------------------------------------------------------------
# Decode (recurrent, O(1) per token)
# ---------------------------------------------------------------------------


class SSMState(NamedTuple):
    conv: jax.Array  # (B, W-1, conv_ch) last inputs
    h: jax.Array  # (B, H, N, P) f32

    @staticmethod
    def init(batch, cfg, dtype):
        conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        return SSMState(
            conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
            h=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
        )


def ssm_decode_step(params, x, state: SSMState, cfg):
    """x (B,1,d) -> (out (B,1,d), new state)."""
    B = x.shape[0]
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    proj = x @ params["in_proj"]
    z, xBC, dt_raw = _split_proj(proj, cfg)
    # conv over [state.conv, xBC]
    hist = jnp.concatenate([state.conv, xBC], axis=1)  # (B, W, ch)
    w = params["conv_w"]  # (ch, W)
    conv_out = jnp.einsum("bwc,cw->bc", hist, w) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)[:, None, :]  # (B,1,ch)
    new_conv = hist[:, 1:, :]

    xs, B_, C_ = _split_xbc(conv_out, cfg)
    xs = xs.reshape(B, H, P)
    B_ = B_.reshape(B, G, N)
    C_ = C_.reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(C_, rep, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["a_log"])
    a = jnp.exp(dt * A[None, :])  # (B,H)

    h = state.h * a[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh * dt[..., None], xs.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)
    y = y + xs.astype(jnp.float32) * params["ssm_d"][None, :, None]
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["gnorm"], cfg.norm_eps)
    return y @ params["out_proj"], SSMState(new_conv, h)
