"""Unified decoder covering all assigned families.

A model is a repeating *pattern* of sub-blocks scanned over ``n_groups``
(scan-over-layers keeps HLO size and compile time flat in depth):

  dense/audio : ['attn','mlp']                      x L
  moe         : ['attn','moe']                      x L
  ssm         : ['ssm']                             x L
  hybrid      : ['ssm']*k + ['shared']              x L/k   (zamba2)
  vlm         : (['attn','mlp']*(k-1)) + ['cross','mlp']  x L/k

'shared' is a weight-shared transformer block (single param copy applied
every group, Zamba2-style). 'cross' attends to stub patch embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.attention import KVCache
from repro.models.layers import dense_init, init_mlp, mlp, rms_norm
from repro.models.moe import init_moe, moe_block
from repro.models.ssm import SSMState, init_ssm, ssm_block, ssm_decode_step
from repro.sharding.axes import constrain


# ---------------------------------------------------------------------------
# Pattern
# ---------------------------------------------------------------------------


def block_pattern(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int]:
    if cfg.family in ("dense", "audio"):
        return ("attn", "mlp"), cfg.num_layers
    if cfg.family == "moe":
        return ("attn", "moe"), cfg.num_layers
    if cfg.family == "ssm":
        return ("ssm",), cfg.num_layers
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        assert cfg.num_layers % k == 0, (cfg.num_layers, k)
        return tuple(["ssm"] * k + ["shared"]), cfg.num_layers // k
    if cfg.family == "vlm":
        k = cfg.cross_attn_every
        assert cfg.num_layers % k == 0, (cfg.num_layers, k)
        pat = tuple(["attn", "mlp"] * (k - 1) + ["cross", "mlp"])
        return pat, cfg.num_layers // k
    raise ValueError(cfg.family)


_CACHED_KINDS = ("attn", "ssm", "shared", "cross")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    pattern, n_groups = block_pattern(cfg)
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    keys = jax.random.split(key, 8)

    def init_group(gkey):
        gk = jax.random.split(gkey, len(pattern))
        gp = {}
        for i, kind in enumerate(pattern):
            name = f"{i}:{kind}"
            if kind == "attn":
                gp[name] = {
                    "norm": jnp.zeros((d,), dtype),
                    "attn": attn_lib.init_attention(gk[i], cfg),
                }
            elif kind == "cross":
                gp[name] = {
                    "norm": jnp.zeros((d,), dtype),
                    "attn": attn_lib.init_attention(gk[i], cfg, cross=True),
                    "gate": jnp.zeros((), dtype),  # llama3.2-vision tanh gate
                }
            elif kind == "mlp":
                gp[name] = {
                    "norm": jnp.zeros((d,), dtype),
                    "mlp": init_mlp(gk[i], d, cfg.d_ff, dtype),
                }
            elif kind == "moe":
                gp[name] = {"norm": jnp.zeros((d,), dtype), "moe": init_moe(gk[i], cfg)}
            elif kind == "ssm":
                gp[name] = {"norm": jnp.zeros((d,), dtype), "ssm": init_ssm(gk[i], cfg)}
            elif kind == "shared":
                gp[name] = {}  # weights live in params['shared']
        return gp

    gkeys = jax.random.split(keys[0], n_groups)
    groups = jax.vmap(init_group)(gkeys)

    params = {
        "embed": dense_init(keys[1], (cfg.vocab_size, d), d, dtype),
        "groups": groups,
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[2], (d, cfg.vocab_size), d, dtype)
    if cfg.family == "hybrid":
        ka, km = jax.random.split(keys[3])
        params["shared"] = {
            "norm_attn": jnp.zeros((d,), dtype),
            "attn": attn_lib.init_attention(ka, cfg),
            "norm_mlp": jnp.zeros((d,), dtype),
            "mlp": init_mlp(km, d, cfg.d_ff, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg, batch) -> jax.Array:
    if cfg.embeds_in:
        h = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        h = params["embed"][batch["tokens"]]
    return h


def _logits(params, cfg, h):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ w


def _apply_block(kind, bp, h, cfg, *, mode, shared, cross_embeds, sliding_window, use_blocked):
    if kind == "attn":
        x = rms_norm(h, bp["norm"], cfg.norm_eps)
        if use_blocked:
            y = attn_lib.flash_self_attention(bp["attn"], x, cfg, sliding_window=sliding_window)
        else:
            y = attn_lib.full_attention(bp["attn"], x, cfg, sliding_window=sliding_window)
        return h + y
    if kind == "mlp":
        x = rms_norm(h, bp["norm"], cfg.norm_eps)
        return h + mlp(bp["mlp"], x, lambda t: constrain(t, "batch", "seq", "ff"))
    if kind == "moe":
        x = rms_norm(h, bp["norm"], cfg.norm_eps)
        y, aux = moe_block(bp["moe"], x, cfg)
        return h + y, aux
    if kind == "ssm":
        x = rms_norm(h, bp["norm"], cfg.norm_eps)
        return h + ssm_block(bp["ssm"], x, cfg)
    if kind == "cross":
        x = rms_norm(h, bp["norm"], cfg.norm_eps)
        y = attn_lib.full_attention(bp["attn"], x, cfg, kv_x=cross_embeds, cross=True)
        return h + jnp.tanh(bp["gate"].astype(jnp.float32)).astype(y.dtype) * y
    if kind == "shared":
        x = rms_norm(h, shared["norm_attn"], cfg.norm_eps)
        if use_blocked:
            y = attn_lib.flash_self_attention(shared["attn"], x, cfg, sliding_window=sliding_window)
        else:
            y = attn_lib.full_attention(shared["attn"], x, cfg, sliding_window=sliding_window)
        h = h + y
        x = rms_norm(h, shared["norm_mlp"], cfg.norm_eps)
        return h + mlp(shared["mlp"], x, lambda t: constrain(t, "batch", "seq", "ff"))
    raise ValueError(kind)


def apply_model(params, cfg: ModelConfig, batch, *, blocked_attn_threshold: int = 8192,
                unroll_groups: bool = False, return_hidden: bool = False):
    """Full-sequence forward. Returns (logits (B,S,V), aux scalar).

    ``unroll_groups`` replaces the scan-over-layer-groups with a python
    loop (used by the dry-run cost-correction compiles, where XLA's
    cost_analysis counts while-loop bodies once)."""
    pattern, n_groups = block_pattern(cfg)
    h = _embed_inputs(params, cfg, batch)
    B, S, _ = h.shape
    h = constrain(h, "batch", "seq", None)
    cross_embeds = batch.get("cross_embeds") if cfg.family == "vlm" else None
    if cross_embeds is not None:
        cross_embeds = cross_embeds.astype(h.dtype)
    use_blocked = S >= blocked_attn_threshold and cfg.family != "ssm"
    sw = cfg.sliding_window
    shared = params.get("shared")

    def group_fn(h, gp):
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pattern):
            out = _apply_block(
                kind, gp[f"{i}:{kind}"], h, cfg, mode="full", shared=shared,
                cross_embeds=cross_embeds, sliding_window=sw, use_blocked=use_blocked,
            )
            if kind == "moe":
                h, a = out
                aux = aux + a
            else:
                h = out
            h = constrain(h, "batch", "seq", None)
        return h, aux

    if cfg.remat:
        group_fn = jax.checkpoint(group_fn)
    if unroll_groups:
        aux_total = jnp.zeros((), jnp.float32)
        for g in range(n_groups):
            gp = jax.tree_util.tree_map(lambda x: x[g], params["groups"])
            h, aux = group_fn(h, gp)
            aux_total = aux_total + aux
    else:
        h, auxs = jax.lax.scan(group_fn, h, params["groups"])
        aux_total = auxs.sum()
    if return_hidden:
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return h, aux_total
    return _logits(params, cfg, h), aux_total


def _labels_and_mask(batch):
    if "labels" in batch:
        labels = batch["labels"]
    else:
        labels = jnp.concatenate(
            [batch["tokens"][:, 1:], batch["tokens"][:, :1]], axis=1
        )
    mask = jnp.ones(labels.shape, jnp.float32)
    mask = mask.at[:, -1].set(0.0)
    return labels, mask


def lm_loss(params, cfg, batch, *, vocab_chunk: int = 0, **kw):
    """Next-token CE loss (labels = inputs shifted, or batch['labels']).

    vocab_chunk > 0 enables the chunked-CE path: the (B,S,V) logits are
    never materialized — the sequence is scanned in chunks with per-chunk
    remat, so the backward recomputes each chunk's logits (the logits +
    f32 CE intermediates are the dominant training activation for
    large-vocab models; measured in §Perf)."""
    from repro.models.layers import cross_entropy

    labels, mask = _labels_and_mask(batch)
    if vocab_chunk <= 0:
        logits, aux = apply_model(params, cfg, batch, **kw)
        return cross_entropy(logits, labels, mask) + aux

    h, aux = apply_model(params, cfg, batch, return_hidden=True, **kw)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    B, S, _ = h.shape
    C = vocab_chunk
    n_chunks = S // C
    assert S % C == 0, (S, C)
    hc = h.reshape(B, n_chunks, C, -1).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, C).swapaxes(0, 1)
    mc = mask.reshape(B, n_chunks, C).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_fn(carry, inp):
        h_c, l_c, m_c = inp
        logits = h_c @ w  # (B,C,V) — lives only inside this chunk
        logits = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * m_c
        return (carry[0] + nll.sum(), carry[1] + m_c.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        chunk_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0) + aux


# ---------------------------------------------------------------------------
# Decode (one token, KV/SSM caches)
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    caches: Any  # per-group stacked cache pytree
    pos: jax.Array  # scalar int32 current position


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int) -> DecodeState:
    """Cache shapes for serving `seq_len` context. Ring buffer if sliding."""
    pattern, n_groups = block_pattern(cfg)
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    ring = cfg.sliding_window > 0 and seq_len > cfg.sliding_window
    cache_len = cfg.sliding_window if ring else seq_len

    def one_group():
        c = {}
        for i, kind in enumerate(pattern):
            if kind in ("attn", "shared"):
                c[f"{i}:{kind}"] = KVCache.init(batch, cache_len, cfg.num_kv_heads, hd, dtype)
            elif kind == "ssm":
                c[f"{i}:{kind}"] = SSMState.init(batch, cfg, dtype)
        return c

    caches = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), one_group()
    )
    return DecodeState(caches=caches, pos=jnp.zeros((), jnp.int32))


def init_cross_kv(params, cfg, patch_embeds):
    """Precompute cross-attn K/V from patch embeddings, stacked per group."""
    pattern, n_groups = block_pattern(cfg)
    hd = cfg.resolved_head_dim
    idx = [i for i, k in enumerate(pattern) if k == "cross"]
    if not idx:
        return None
    (i,) = idx

    def per_group(gp):
        ap = gp[f"{i}:cross"]["attn"]
        x = patch_embeds.astype(ap["wk"].dtype)
        k = (x @ ap["wk"]).reshape(*x.shape[:-1], cfg.num_kv_heads, hd)
        v = (x @ ap["wv"]).reshape(*x.shape[:-1], cfg.num_kv_heads, hd)
        return {"k": k, "v": v}

    return jax.vmap(per_group)(params["groups"])


def decode_step(params, cfg: ModelConfig, token_or_embed, state: DecodeState,
                cross_kv=None, *, unroll_groups: bool = False):
    """One decode step. token (B,) int32 or embed (B,1,d). Returns
    (logits (B,V), new DecodeState)."""
    pattern, n_groups = block_pattern(cfg)
    if cfg.embeds_in:
        h = token_or_embed.astype(jnp.dtype(cfg.dtype))
    else:
        h = params["embed"][token_or_embed][:, None, :]
    ring = cfg.sliding_window > 0
    pos = state.pos
    shared = params.get("shared")

    def group_fn(h, xs):
        gp, gc, gcross = xs
        new_gc = dict(gc)
        for i, kind in enumerate(pattern):
            name = f"{i}:{kind}"
            if kind in ("attn", "shared"):
                bp = shared if kind == "shared" else gp[name]
                nrm = bp["norm_attn"] if kind == "shared" else gp[name]["norm"]
                ap = bp["attn"]
                x = rms_norm(h, nrm, cfg.norm_eps)
                cache = KVCache(*gc[name])
                y, new_cache = attn_lib.decode_attention(
                    ap, x, cache, pos, cfg, ring=cfg.sliding_window > 0 and cache.k.shape[1] == cfg.sliding_window
                )
                h = h + y
                new_gc[name] = new_cache
                if kind == "shared":
                    x = rms_norm(h, shared["norm_mlp"], cfg.norm_eps)
                    h = h + mlp(shared["mlp"], x)
            elif kind == "mlp":
                x = rms_norm(h, gp[name]["norm"], cfg.norm_eps)
                h = h + mlp(gp[name]["mlp"], x)
            elif kind == "moe":
                x = rms_norm(h, gp[name]["norm"], cfg.norm_eps)
                y, _ = moe_block(gp[name]["moe"], x, cfg)
                h = h + y
            elif kind == "ssm":
                x = rms_norm(h, gp[name]["norm"], cfg.norm_eps)
                y, new_s = ssm_decode_step(gp[name]["ssm"], x, SSMState(*gc[name]), cfg)
                h = h + y
                new_gc[name] = new_s
            elif kind == "cross":
                x = rms_norm(h, gp[name]["norm"], cfg.norm_eps)
                y = attn_lib.cross_decode_attention(
                    gp[name]["attn"], x, gcross["k"], gcross["v"], cfg
                )
                g = jnp.tanh(gp[name]["gate"].astype(jnp.float32)).astype(y.dtype)
                h = h + g * y
        return h, new_gc

    if cross_kv is None:
        pattern_has_cross = any(k == "cross" for k in pattern)
        assert not pattern_has_cross, "vlm decode needs cross_kv"
        cross_dummy = jax.tree_util.tree_map(lambda x: x, {"k": jnp.zeros((n_groups, 1)), "v": jnp.zeros((n_groups, 1))})
    else:
        cross_dummy = cross_kv
    xs = (params["groups"], state.caches, cross_dummy)
    if unroll_groups:
        new_list = []
        for g in range(n_groups):
            xg = jax.tree_util.tree_map(lambda x: x[g], xs)
            h, gc_new = group_fn(h, xg)
            new_list.append(gc_new)
        new_caches = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *new_list)
    else:
        h, new_caches = jax.lax.scan(group_fn, h, xs)
    logits = _logits(params, cfg, h)[:, 0, :]
    return logits, DecodeState(caches=new_caches, pos=pos + 1)
