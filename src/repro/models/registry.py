"""Model registry — uniform build/apply surface over the unified decoder."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from repro.configs.base import ModelConfig, get_config, get_reduced
from repro.models import model as M


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable  # (key) -> params
    apply: Callable  # (params, batch) -> (logits, aux)
    loss: Callable  # (params, batch) -> scalar
    init_decode_state: Callable  # (batch, seq_len) -> DecodeState
    decode_step: Callable  # (params, tok, state, cross_kv=None) -> (logits, state)
    init_cross_kv: Callable  # (params, patch_embeds) -> cross kv or None


def build_model(cfg_or_name) -> Model:
    cfg = cfg_or_name if isinstance(cfg_or_name, ModelConfig) else get_config(cfg_or_name)
    return Model(
        cfg=cfg,
        init=lambda key: M.init_params(key, cfg),
        apply=lambda params, batch, **kw: M.apply_model(params, cfg, batch, **kw),
        loss=lambda params, batch, **kw: M.lm_loss(params, cfg, batch, **kw),
        init_decode_state=lambda batch, seq_len: M.init_decode_state(cfg, batch, seq_len),
        decode_step=lambda params, tok, state, cross_kv=None: M.decode_step(
            params, cfg, tok, state, cross_kv
        ),
        init_cross_kv=lambda params, patch_embeds: M.init_cross_kv(params, cfg, patch_embeds),
    )


def build_reduced(name: str) -> Model:
    return build_model(get_reduced(name))
