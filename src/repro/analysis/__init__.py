"""`repro.analysis`: the repo's JAX-aware static analyzer.

The correctness story of this reproduction rests on invariants the type
system never sees: the per-event RNG key split discipline every
replay-vs-scan parity battery depends on, no Python branching on traced
values inside `simulate`'s scan, hashable jit keys for every config, no
host synchronization inside compiled bodies, and the documented
``(N, Dflat)`` / ``(D, N, Dflat)`` / ``(D, N, N)`` plane contracts.
This package encodes them as lint rules over the Python AST — pure
stdlib, no jax import required, so the lint gate runs anywhere.

Usage:

    python -m repro.analysis src tests            # human-readable
    python -m repro.analysis src tests --strict   # CI gate (warnings fail)
    python -m repro.analysis src --json report.json

Suppressions are per-rule and *must* carry a reason::

    x = f(key)  # repro-lint: disable=RNG-KEY-REUSE(parity oracle reuses
                # the stream on purpose)

A suppression without a reason does not suppress — it raises
SUPPRESS-NO-REASON instead. See EXPERIMENTS.md "Static analysis" for
the rule table and policy.
"""
from repro.analysis.core import (
    Finding,
    Rule,
    RULES,
    SourceFile,
    analyze_paths,
    iter_python_files,
    register_rule,
    report_json,
)

# Importing the rules package registers every built-in rule.
from repro.analysis import rules as _rules  # noqa: F401

__all__ = [
    "Finding",
    "Rule",
    "RULES",
    "SourceFile",
    "analyze_paths",
    "iter_python_files",
    "register_rule",
    "report_json",
]
