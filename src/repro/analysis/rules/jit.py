"""JIT-RECOMPILE-HAZARD: things that silently defeat the jit cache.

Three sub-patterns, all directly relevant to `simulate_sweep`'s
one-compile promise and the ROADMAP's 10^4+-client scale-up:

1. a directly-jitted function takes a ``dict``/``list``/``set``
   parameter (by annotation or mutable default) that is not in
   ``static_argnames`` — unhashable leaves force retraces or errors;
2. a jit wrapper is built where it cannot be cached: ``jax.jit(f)(x)``
   immediately invoked (the wrapper — and its compile cache — is
   discarded after one call), or ``jax.jit`` called inside a
   ``for``/``while`` body (a fresh wrapper, and a fresh trace, per
   iteration). Binding a wrapper once inside a function and reusing
   it is fine and is not flagged;
3. a jitted function closes over a module-level ``np``/``jnp`` array
   constant — the constant is baked into the jaxpr (bloating it and,
   for `np`, re-transferred per trace); pass it as an argument or hoist
   it into the carry. Reported as a *warning* (it is a cost, not a
   bug), so it gates only under ``--strict``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set

from repro.analysis.core import Finding, SourceFile, register_rule
from repro.analysis.jaxctx import FunctionIndex, _is_jit_ref, dotted

RULE = "JIT-RECOMPILE-HAZARD"

_MUTABLE_ANNOS = {"dict", "list", "set", "Dict", "List", "Set",
                  "MutableMapping", "DefaultDict"}
_ARRAY_MAKERS = {"array", "asarray", "ones", "zeros", "arange", "linspace",
                 "eye", "full", "empty", "identity"}
_ARRAY_ROOTS = {"np", "numpy", "onp", "jnp"}


def _mutable_annotation(ann: Optional[ast.AST]) -> Optional[str]:
    if ann is None:
        return None
    node = ann.value if isinstance(ann, ast.Subscript) else ann
    d = dotted(node)
    if d is not None and d[-1] in _MUTABLE_ANNOS:
        return d[-1]
    return None


def _module_array_constants(tree: ast.Module) -> Dict[str, int]:
    """name -> lineno of module-level `X = np.array(...)`-style binds."""
    consts: Dict[str, int] = {}
    for stmt in tree.body:
        if not isinstance(stmt, ast.Assign) \
                or not isinstance(stmt.value, ast.Call):
            continue
        d = dotted(stmt.value.func)
        if d is None or len(d) < 2 or d[0] not in _ARRAY_ROOTS \
                or d[-1] not in _ARRAY_MAKERS:
            continue
        for t in stmt.targets:
            if isinstance(t, ast.Name):
                consts[t.id] = stmt.lineno
    return consts


@register_rule(
    RULE,
    "jit cache defeats: unhashable (dict/list/set) jit params outside "
    "static_argnames, jax.jit called per-invocation, jitted closure over "
    "module-level array constants")
def check_recompile_hazards(src: SourceFile) -> Iterator[Finding]:
    if src.tree is None:
        return
    index = FunctionIndex(src.tree)
    jitted = [c for c in index.traced_contexts()
              if c.origin in ("@jax.jit", "jax.jit(...)")]

    # 1. unhashable params not marked static
    for ctx in jitted:
        a = ctx.func.args
        pos = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
        pos_defaults = [None] * (len(a.posonlyargs) + len(a.args)
                                 - len(a.defaults)) \
            + list(a.defaults) + list(a.kw_defaults)
        for p, default in zip(pos, pos_defaults):
            if p.arg not in ctx.traced_params:
                continue  # already static (argnames/argnums/heuristics)
            kind = _mutable_annotation(p.annotation)
            if kind is None and isinstance(default, (ast.Dict, ast.List,
                                                     ast.Set)):
                kind = type(default).__name__.lower()
            if kind is not None:
                yield src.finding(
                    RULE, p,
                    f"jitted '{ctx.func.name}' takes {kind} param "
                    f"'{p.arg}' outside static_argnames — unhashable jit "
                    "key forces retraces; mark it static or pass arrays")

    # 2a. immediately-invoked wrapper: jax.jit(f)(x)
    immediate: Set[int] = set()
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Call) \
                and _is_jit_ref(node.func.func):
            immediate.add(id(node.func))
            yield src.finding(
                RULE, node,
                "jax.jit(f)(...) invoked immediately: the wrapper and its "
                "compile cache are discarded after one call — bind it once "
                "and reuse it")
    # 2b. jit wrapper built inside a loop body (deduped across nested
    # loops and against the immediate-invoke pattern above)
    in_loop: Set[int] = set()
    for node in ast.walk(src.tree):
        if isinstance(node, (ast.For, ast.While)):
            for sub in ast.walk(node):
                if sub is not node and isinstance(sub, ast.Call) \
                        and _is_jit_ref(sub.func):
                    in_loop.add(id(sub))
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Call) and id(node) in in_loop \
                and id(node) not in immediate:
            yield src.finding(
                RULE, node,
                "jax.jit called inside a loop body: a fresh wrapper (and "
                "a fresh trace) per iteration; hoist the jit out of the "
                "loop")

    # 3. jitted closure over module-level array constants
    consts = _module_array_constants(src.tree)
    if consts:
        for ctx in index.traced_contexts():
            if ctx.origin.startswith("called from"):
                continue  # report at the jit/scan boundary, not helpers
            params = {p.arg for p in (list(ctx.func.args.posonlyargs)
                                      + list(ctx.func.args.args)
                                      + list(ctx.func.args.kwonlyargs))}
            seen: Set[str] = set()
            for node in ast.walk(ctx.func):
                if isinstance(node, ast.Name) \
                        and isinstance(node.ctx, ast.Load) \
                        and node.id in consts and node.id not in params \
                        and node.id not in seen:
                    seen.add(node.id)
                    yield src.finding(
                        RULE, node,
                        f"'{ctx.func.name}' ({ctx.origin}) closes over "
                        f"module-level array constant '{node.id}' (bound "
                        f"at line {consts[node.id]}); it is baked into "
                        "every trace — pass it as an argument",
                        severity="warning")
