"""DTYPE-PLANE-CONTRACT: the shape planes must stay documented.

The whole engine moves data through a fixed set of named planes —
``(N, Dflat)`` client flats, ``(D, N, Dflat)`` delay ring payloads,
``(D, N, N)`` weight/delay rings, ``(N, Dopt)`` optimizer slabs,
``(S, N, K)`` / ``(J, N, M)`` sharded gossip buffers. Public functions
in `core/flat.py`, `core/protocol.py`, `events/*`, `kernels/gossip/*`
that take one of these plane parameters must carry a docstring that
names the parameter next to its shape tuple, and the documented shape
must be one of the contracts below — a mismatch means either the doc or
the code drifted.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, Set

from repro.analysis.core import Finding, SourceFile, register_rule

RULE = "DTYPE-PLANE-CONTRACT"

# Directories/files the contract applies to (path fragments, / separated).
_SCOPE = ("core/flat.py", "core/protocol.py", "events/", "kernels/gossip/")

# plane param name -> allowed documented shapes (whitespace-insensitive)
PLANE_PARAMS: Dict[str, Set[str]] = {
    "flat": {"(N,Dflat)", "(Dflat,)"},
    "flats": {"(N,Dflat)"},
    "pending": {"(N,Dflat)", "(N,K)", "(N,...)"},
    "deltas": {"(N,Dflat)", "(N,K)"},
    "buffer": {"(D,N,Dflat)", "(D,N,...)"},
    "ring": {"(D,N,Dflat)", "(S,N,K)"},
    "w_ring": {"(D,N,N)"},
    "delay_ring": {"(D,N,N)"},
    "deadline_ring": {"(D,N,N)"},
    "w_stack": {"(D,N,N)", "(J,N,N)", "(J,N,M)"},
    "opt_state": {"(N,Dopt)"},
    "q": {"(N,N)"},
}

_SHAPE_RE_TMPL = r"\b{name}\b[^()]{{0,60}}\(([^()]*)\)"


def _in_scope(path: str) -> bool:
    p = path.replace("\\", "/")
    return any(frag in p for frag in _SCOPE)


def _documented_shapes(doc: str, name: str) -> list:
    """Every `(...)` tuple documented within reach of a `name` mention —
    a docstring passes if *any* of them matches the contract (prose may
    mention the param before the annotated line does)."""
    pat = _SHAPE_RE_TMPL.format(name=re.escape(name))
    return ["(" + re.sub(r"\s+", "", m.group(1)) + ")"
            for m in re.finditer(pat, doc)]


def _public_functions(tree: ast.Module) -> Iterator[ast.FunctionDef]:
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and not stmt.name.startswith("_"):
            yield stmt


@register_rule(
    RULE,
    "public plane-carrying functions in core/flat, core/protocol, events/*, "
    "kernels/gossip/* must docstring-annotate (N, Dflat)/(D, N, Dflat)/"
    "(D, N, N) shapes; documented shapes must match the contract table")
def check_plane_contracts(src: SourceFile) -> Iterator[Finding]:
    if src.tree is None or not _in_scope(src.path):
        return
    for func in _public_functions(src.tree):
        a = func.args
        params = [p.arg for p in (list(a.posonlyargs) + list(a.args)
                                  + list(a.kwonlyargs))]
        plane_params = [p for p in params if p in PLANE_PARAMS]
        if not plane_params:
            continue
        doc = ast.get_docstring(func, clean=True)
        if not doc:
            yield src.finding(
                RULE, func,
                f"public '{func.name}' takes plane param(s) "
                f"{', '.join(plane_params)} but has no shape-contract "
                "docstring")
            continue
        for p in plane_params:
            shapes = _documented_shapes(doc, p)
            if not shapes:
                yield src.finding(
                    RULE, func,
                    f"docstring of '{func.name}' does not annotate the "
                    f"shape of plane param '{p}' — document it as one of "
                    f"{sorted(PLANE_PARAMS[p])}")
            elif not any(s in PLANE_PARAMS[p] for s in shapes):
                yield src.finding(
                    RULE, func,
                    f"docstring of '{func.name}' documents '{p}' as "
                    f"{shapes[0]}, but the plane contract allows "
                    f"{sorted(PLANE_PARAMS[p])} — doc or code drifted")
