"""TRACED-PY-BRANCH and HOST-SYNC-IN-JIT.

Both rules share one machine: `jaxctx.FunctionIndex` decides which
functions are traced contexts (direct jit, combinator body, known
scan-body entry point, or in-module call propagation), and
`tracedness.TraceWalker` walks each context forward, flagging Python
control flow ("branch") and device->host pulls ("host-sync") on traced
values. See those modules for the staticness heuristics that keep the
false-positive rate near zero on this codebase.
"""
from __future__ import annotations

from typing import Iterator, Set, Tuple

from repro.analysis.core import Finding, SourceFile, register_rule
from repro.analysis.jaxctx import FunctionIndex
from repro.analysis.tracedness import analyze_function

_BRANCH = "TRACED-PY-BRANCH"
_SYNC = "HOST-SYNC-IN-JIT"


def _hazards(src: SourceFile) -> Iterator[Tuple[str, object, str, str]]:
    """(kind, node, detail, origin) across every traced context, deduped
    by (kind, line, col) — nested defs are walked both as closures of
    their parent and, when scanned, as contexts of their own."""
    if src.tree is None:
        return
    index = FunctionIndex(src.tree)
    seen: Set[Tuple[str, int, int]] = set()
    for ctx in index.traced_contexts():
        walker = analyze_function(ctx.func, ctx.traced_params)
        for kind, node, detail in walker.hazards:
            key = (kind, node.lineno, node.col_offset)
            if key in seen:
                continue
            seen.add(key)
            yield kind, node, detail, ctx.origin


@register_rule(
    _BRANCH,
    "Python if/while/assert/bool()/ternary on a traced value inside a "
    "jitted function, scan body, or lax.cond/switch branch")
def check_traced_branch(src: SourceFile) -> Iterator[Finding]:
    for kind, node, detail, origin in _hazards(src):
        if kind == "branch":
            yield src.finding(_BRANCH, node, f"{detail} [{origin}]")


@register_rule(
    _SYNC,
    "float()/int()/.item()/.tolist()/np.asarray/print on a traced value "
    "inside a compiled body (device->host sync)")
def check_host_sync(src: SourceFile) -> Iterator[Finding]:
    for kind, node, detail, origin in _hazards(src):
        if kind == "host-sync":
            yield src.finding(_SYNC, node, f"{detail} [{origin}]")
