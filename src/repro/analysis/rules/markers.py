"""MARKER-DISCIPLINE: heavy test batteries must be marked ``slow``.

ROADMAP tiering keeps tier-1 (`pytest -m "not slow"`) at ~2 minutes.
Two patterns must therefore carry ``@pytest.mark.slow`` (per test) or a
module-level ``pytestmark = pytest.mark.slow``:

* test *files* whose names match the battery patterns
  (parity / mesh / theory / property / system / dryrun);
* hypothesis tests (any ``@given``-decorated test), which multiply
  their body by the example count.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterator, Set

from repro.analysis.core import Finding, SourceFile, register_rule
from repro.analysis.jaxctx import dotted

RULE = "MARKER-DISCIPLINE"

SLOW_FILE_PATTERNS = re.compile(
    r"test_.*(parity|mesh|theory|property|system|dryrun)")


def _is_test_file(path: str) -> bool:
    p = path.replace("\\", "/")
    return "tests/" in p and os.path.basename(p).startswith("test_")


def _has_module_slow_mark(tree: ast.Module) -> bool:
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign):
            names = [t.id for t in stmt.targets if isinstance(t, ast.Name)]
            if "pytestmark" in names and "slow" in ast.dump(stmt.value):
                return True
    return False


def _decorator_names(func: ast.FunctionDef) -> Set[str]:
    out: Set[str] = set()
    for deco in func.decorator_list:
        node = deco.func if isinstance(deco, ast.Call) else deco
        d = dotted(node)
        if d is not None:
            out.add(".".join(d))
    return out


def _test_functions(tree: ast.Module) -> Iterator[ast.FunctionDef]:
    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and stmt.name.startswith("test"):
            yield stmt
        elif isinstance(stmt, ast.ClassDef) and stmt.name.startswith("Test"):
            for sub in stmt.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and sub.name.startswith("test"):
                    yield sub


@register_rule(
    RULE,
    "parity/mesh/theory/property/system battery files and @given "
    "(hypothesis) tests must carry @pytest.mark.slow so tier-1 stays fast")
def check_marker_discipline(src: SourceFile) -> Iterator[Finding]:
    if src.tree is None or not _is_test_file(src.path):
        return
    if _has_module_slow_mark(src.tree):
        return
    basename = os.path.basename(src.path)
    battery = SLOW_FILE_PATTERNS.search(basename) is not None
    for func in _test_functions(src.tree):
        decos = _decorator_names(func)
        slow = any(d.endswith("mark.slow") or d == "slow" for d in decos)
        if slow:
            continue
        hypothesis = any(d == "given" or d.endswith(".given")
                         for d in decos)
        if battery:
            yield src.finding(
                RULE, func,
                f"'{func.name}' in battery file {basename} lacks "
                "@pytest.mark.slow (add it, or a module-level pytestmark)")
        elif hypothesis:
            yield src.finding(
                RULE, func,
                f"hypothesis test '{func.name}' (@given) lacks "
                "@pytest.mark.slow — example sweeps don't belong in tier-1")
