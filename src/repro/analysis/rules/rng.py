"""RNG-KEY-REUSE: one PRNG key, one consumption.

Every replay-vs-scan parity battery in `tests/test_event_engine.py`
depends on the per-event split discipline: a key is consumed exactly
once — by a sampler, by `split`, or by `fold_in` — and any further
randomness uses a *fresh* subkey. Feeding the same key to two
consumers yields correlated (often identical) draws, which is exactly
the class of bug that keeps two engines in spurious agreement.

The rule runs a small flow-ordered state machine per function:

* a *key entity* is a dotted name (``key``, ``state.key``) or a
  constant-index subscript (``ks[0]``);
* passing an entity as the first positional argument (or ``key=``
  keyword) of a `jax.random` consumer marks it consumed;
* rebinding the entity — ``key, sub = jax.random.split(key)``,
  ``key = fold_in(key, i)``, or any other assignment — renews it;
* a second consumption without a renewal is a finding.

``fold_in(key, i)`` does *not* consume: deriving per-iteration streams
from one base key is the house idiom (see `draco_window`'s 8-way split
vs the `fold_in` ladders in `launch/train.py` and the test suite).

`if` branches are walked independently (consumed-state unioned, except
branches that terminate in return/raise — their state never reaches
the fall-through); loop bodies are walked twice so a loop-carried key
consumed each iteration without a re-split is caught.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.core import Finding, SourceFile, register_rule
from repro.analysis.jaxctx import dotted

RULE = "RNG-KEY-REUSE"

# jax.random consumers whose first argument is a key. `fold_in` is
# deliberately absent: `fold_in(key, i)` *derives* a stream tagged by
# its data argument and is this repo's idiom for reusing one base key
# across loop iterations / independent draws — it never collides with
# a draw from the key itself the way a second sampler call does.
_CONSUMERS = {
    "split", "clone",
    "normal", "uniform", "bernoulli", "randint", "choice", "permutation",
    "shuffle", "categorical", "gumbel", "exponential", "poisson", "gamma",
    "beta", "dirichlet", "laplace", "logistic", "cauchy", "t", "rademacher",
    "truncated_normal", "multivariate_normal", "loggamma", "maxwell",
    "geometric", "binomial", "ball", "orthogonal", "bits",
}
_RANDOM_ROOTS = {"random", "jrandom", "jr"}

Entity = Tuple  # ("state", "key") or ("ks", 3)


def _is_random_call(call: ast.Call) -> Optional[str]:
    """Name of the jax.random consumer/producer, or None."""
    d = dotted(call.func)
    if d is None:
        return None
    name = d[-1]
    if name not in _CONSUMERS | {"PRNGKey", "key"}:
        return None
    if d[0] in {"np", "numpy", "onp", "scipy", "torch"}:
        return None  # np.random.* takes data, not keys
    if len(d) >= 2 and d[-2] in _RANDOM_ROOTS:
        return name
    if len(d) == 1 and name in {"PRNGKey", "fold_in"}:
        return name  # common `from jax.random import PRNGKey, fold_in`
    return None


def _terminates(stmts: List[ast.stmt]) -> bool:
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


def _entity(node: ast.AST) -> Optional[Entity]:
    d = dotted(node)
    if d is not None:
        return d
    if isinstance(node, ast.Subscript):
        base = dotted(node.value)
        idx = node.slice
        if base is not None and isinstance(idx, ast.Constant) \
                and isinstance(idx.value, int):
            return base + (idx.value,)
    return None


class _KeyFlow:
    def __init__(self) -> None:
        self.consumed: Dict[Entity, ast.AST] = {}
        self.findings: List[Tuple[ast.AST, Entity, int]] = []

    # -- state transitions ---------------------------------------------------

    def _renew(self, entity: Entity) -> None:
        for e in [k for k in self.consumed
                  if k == entity or k[:len(entity)] == entity]:
            del self.consumed[e]

    def _consume(self, entity: Entity, node: ast.AST) -> None:
        prev = self.consumed.get(entity)
        if prev is not None:
            self.findings.append((node, entity, prev.lineno))
        else:
            self.consumed[entity] = node

    def _key_arg(self, call: ast.Call) -> Optional[ast.AST]:
        if call.args and not isinstance(call.args[0], ast.Starred):
            return call.args[0]
        for kw in call.keywords:
            if kw.arg == "key":
                return kw.value
        return None

    # -- expression walk (in-order, so nested calls consume first) -----------

    def visit_expr(self, node: ast.AST) -> None:
        if node is None:
            return
        if isinstance(node, ast.Call):
            for arg in node.args:
                self.visit_expr(arg.value if isinstance(arg, ast.Starred)
                                else arg)
            for kw in node.keywords:
                self.visit_expr(kw.value)
            name = _is_random_call(node)
            if name in _CONSUMERS:
                arg = self._key_arg(node)
                ent = _entity(arg) if arg is not None else None
                if ent is not None:
                    self._consume(ent, node)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return  # separate scope; module-level pass visits defs
        for child in ast.iter_child_nodes(node):
            self.visit_expr(child)

    # -- statements ----------------------------------------------------------

    def _bind_target(self, target: ast.AST) -> None:
        ent = _entity(target)
        if ent is not None:
            self._renew(ent)
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind_target(elt.value if isinstance(elt, ast.Starred)
                                  else elt)

    def visit_block(self, stmts) -> None:
        for stmt in stmts:
            self.visit_stmt(stmt)

    def visit_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            self.visit_expr(stmt.value)
            for t in stmt.targets:
                self._bind_target(t)
        elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
            if stmt.value is not None:
                self.visit_expr(stmt.value)
            self._bind_target(stmt.target)
        elif isinstance(stmt, ast.If):
            self.visit_expr(stmt.test)
            saved = dict(self.consumed)
            self.visit_block(stmt.body)
            after_body = dict(self.consumed)
            self.consumed = dict(saved)
            self.visit_block(stmt.orelse)
            # a branch ending in return/raise never reaches the
            # fall-through: its consumed keys don't leak past the If
            if stmt.orelse and _terminates(stmt.orelse):
                self.consumed = dict(saved)
            if not _terminates(stmt.body):
                self.consumed.update(after_body)
        elif isinstance(stmt, (ast.For, ast.While)):
            if isinstance(stmt, ast.For):
                self.visit_expr(stmt.iter)
                self._bind_target(stmt.target)
            else:
                self.visit_expr(stmt.test)
            self.visit_block(stmt.body)  # twice: loop-carried reuse
            self.visit_block(stmt.body)
            self.visit_block(stmt.orelse)
        elif isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                self.visit_expr(stmt.value)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self.visit_expr(item.context_expr)
            self.visit_block(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.visit_block(stmt.body)
            for h in stmt.handlers:
                self.visit_block(h.body)
            self.visit_block(stmt.orelse)
            self.visit_block(stmt.finalbody)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return  # nested defs get their own scope in the module pass
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.stmt):
                    self.visit_stmt(child)
                elif isinstance(child, ast.expr):
                    self.visit_expr(child)


@register_rule(
    RULE,
    "a jax.random key consumed by two sampling/split calls without an "
    "intervening split/fold_in renewal (correlated draws)")
def check_key_reuse(src: SourceFile) -> Iterator[Finding]:
    if src.tree is None:
        return
    funcs = [n for n in ast.walk(src.tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    scopes = [src.tree] + funcs
    for scope in scopes:
        flow = _KeyFlow()
        if isinstance(scope, ast.Module):
            for stmt in scope.body:
                if not isinstance(stmt, (ast.FunctionDef,
                                         ast.AsyncFunctionDef,
                                         ast.ClassDef)):
                    flow.visit_stmt(stmt)
        else:
            flow.visit_block(scope.body)
        reported: Set[Tuple[int, Entity]] = set()
        for node, entity, first_line in flow.findings:
            k = (node.lineno, entity)
            if k in reported:
                continue
            reported.add(k)
            name = ".".join(str(p) for p in entity)
            yield src.finding(
                RULE, node,
                f"key '{name}' already consumed at line {first_line}; "
                "split/fold_in it before drawing again")
