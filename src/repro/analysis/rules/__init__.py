"""Built-in rules. Importing this package registers them all.

| rule id              | guards                                            |
|----------------------|---------------------------------------------------|
| RNG-KEY-REUSE        | one key, one consumption (split/fold_in renews)   |
| TRACED-PY-BRANCH     | no Python control flow on traced values           |
| HOST-SYNC-IN-JIT     | no device->host pulls inside compiled bodies      |
| JIT-RECOMPILE-HAZARD | unhashable jit args / per-call jit / array consts |
| DTYPE-PLANE-CONTRACT | documented (N, Dflat)/(D, N, Dflat)/(D, N, N)     |
| MARKER-DISCIPLINE    | parity/mesh/hypothesis batteries marked slow      |
"""
from repro.analysis.rules import (  # noqa: F401  (import = register)
    contracts,
    jit,
    markers,
    rng,
    trace,
)
