"""Traced-context inference: which functions run under `jax.jit`, and
which of their names hold traced values.

Three ways a function becomes a *traced context*:

1. **Direct jit**: decorated with ``@jax.jit`` / ``@partial(jax.jit,
   static_argnames=...)``, or bound via ``g = jax.jit(f, ...)``.
   ``static_argnames`` / ``static_argnums`` mark the static params.
2. **Combinator body**: passed (by name, in the same module) to
   ``jax.lax.scan`` / ``cond`` / ``switch`` / ``while_loop`` /
   ``fori_loop`` / ``jax.vmap`` / ``jax.grad`` / ... — every param is
   traced.
3. **In-module call propagation**: called from a traced context; a
   param is traced iff some call site binds it to an expression that
   references a traced name. Iterated to a fixpoint, so
   ``run_windows (jit) -> step (scan body) -> draco_window`` marks
   `draco_window`'s state/q/adj/data params traced while its `cfg`
   (bound to a static name) stays static.

Cross-module call sites can't be seen from one AST, so the known scan
bodies of this repo (`repro.api.simulate`'s algorithm `step` adapters,
`repro.events.engine.event_step`, `core.protocol.draco_window*`) are
seeded via ``TRACED_ENTRY_POINTS``.

Staticness heuristics (tuned to this codebase, kept deliberately
conservative so every finding is actionable):

- params named in ``STATIC_PARAM_NAMES`` (configs, tasks, specs,
  callables — all hashable jit aux data here) are static;
- params with literal int/float/bool/str defaults or annotations are
  static (they are Python-level knobs bound via `partial`);
- attribute chains are cut static at ``STATIC_ATTRS`` — `ctx.cfg`,
  `ctx.task`, `ctx.flat_spec` ride `SimContext`'s pytree aux slot, and
  `.shape` / `.ndim` / `.dtype` / `.size` are static trace metadata;
- ``x is None`` / ``x is not None`` tests, and ``isinstance`` /
  ``hasattr`` / ``callable`` / ``len`` calls, are Python-structure
  checks, never value reads;
- inside an ``if isinstance(x, ...)`` body, `x` is narrowed static
  (the `_psi_accept` static-vs-traced psi dispatch pattern).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Set, Tuple

# Functions scanned/jitted from *other* modules (the known scan-body
# call sites the rule docs name): every param but the listed statics is
# treated as traced.
TRACED_ENTRY_POINTS: Dict[str, Set[str]] = {
    # core.protocol — scan bodies of run_windows / the api adapters
    "draco_window": {"cfg", "task", "spec"},
    "draco_window_legacy": {"cfg", "loss_fn"},
    # events.engine — per-tape-row scan body of the unified simulate scan;
    # ctx is a traced pytree (its cfg/task/flat_spec aux slots are cut
    # static by STATIC_ATTRS)
    "event_step": set(),
    # core.baselines — round fns driven by the api adapters' scan
    "sync_symm_round": {"cfg", "task"},
    "sync_push_round": {"cfg", "task"},
    "async_symm_round": {"cfg", "task"},
    "async_push_round": {"cfg", "task"},
}

STATIC_PARAM_NAMES = {
    "self", "cls", "cfg", "config", "task", "spec", "loss_fn", "eval_fn",
    "final_fn", "metric_name", "algo", "method", "mesh", "client_axes",
    "axis_name", "num_steps", "num_windows", "num_rounds", "eval_every",
}

# Attribute names that cut a traced chain static: SimContext aux slots
# plus array trace metadata.
STATIC_ATTRS = {"cfg", "task", "flat_spec", "shape", "ndim", "dtype", "size"}

# Structural predicates — reading them never forces a traced value.
STRUCTURAL_CALLS = {"isinstance", "hasattr", "callable", "len", "type",
                    "issubclass", "getattr", "id", "repr"}

_JIT_NAMES = {("jax", "jit"), ("jit",)}
# combinator -> indices of its function-valued operands
_COMBINATORS = {
    "scan": (0,), "while_loop": (0, 1), "fori_loop": (2,),
    "cond": (1, 2, 3), "switch": (1,), "map": (0,),
    "associative_scan": (0,), "vmap": (0,), "pmap": (0,), "grad": (0,),
    "value_and_grad": (0,), "checkpoint": (0,), "remat": (0,),
    "custom_jvp": (0,), "custom_vjp": (0,),
}


def dotted(node: ast.AST) -> Optional[Tuple[str, ...]]:
    """`a.b.c` -> ("a", "b", "c"); None for non-name-rooted expressions."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _is_jit_ref(node: ast.AST) -> bool:
    d = dotted(node)
    return d is not None and (d in _JIT_NAMES or d[-1] == "jit")


def _literal_static_default(default: Optional[ast.AST]) -> bool:
    return isinstance(default, ast.Constant) and isinstance(
        default.value, (int, float, bool, str))


def _static_annotation(ann: Optional[ast.AST]) -> bool:
    if ann is None:
        return False
    d = dotted(ann)
    return d is not None and d[-1] in {"int", "float", "bool", "str"}


def _parse_static_argnames(call: ast.Call) -> Set[str]:
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.add(n.value)
    return names


def _parse_static_argnums(call: ast.Call) -> Set[int]:
    nums: Set[int] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.add(n.value)
    return nums


@dataclasses.dataclass
class TracedContext:
    func: ast.FunctionDef
    origin: str  # human-readable: "@jax.jit", "lax.scan body", ...
    traced_params: Set[str]


def _param_names(func) -> List[str]:
    a = func.args
    return ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
            + [p.arg for p in a.kwonlyargs])


def _default_static_params(func) -> Set[str]:
    """Params static by naming convention, literal default or annotation."""
    a = func.args
    static: Set[str] = set()
    pos = list(a.posonlyargs) + list(a.args)
    # defaults align with the *tail* of the positional params
    pos_defaults = [None] * (len(pos) - len(a.defaults)) + list(a.defaults)
    for p, d in zip(pos, pos_defaults):
        if (p.arg in STATIC_PARAM_NAMES or _literal_static_default(d)
                or _static_annotation(p.annotation)):
            static.add(p.arg)
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if (p.arg in STATIC_PARAM_NAMES or _literal_static_default(d)
                or _static_annotation(p.annotation)):
            static.add(p.arg)
    return static


class FunctionIndex:
    """Per-module index of functions and their traced contexts."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        self.functions: List[ast.FunctionDef] = []
        self.by_name: Dict[str, ast.FunctionDef] = {}
        self.parent: Dict[ast.AST, Optional[ast.AST]] = {}
        self._collect(tree, None)
        self.contexts: Dict[ast.FunctionDef, TracedContext] = {}
        self._find_direct_jit()
        self._find_combinator_bodies()
        self._seed_entry_points()
        self._propagate_calls()

    # -- collection ---------------------------------------------------------

    def _collect(self, node: ast.AST, parent_func) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.append(child)
                self.parent[child] = parent_func
                # innermost definition wins for name lookup, matching the
                # "same scope or enclosing" resolution rules closely enough
                self.by_name.setdefault(child.name, child)
                self._collect(child, child)
            else:
                self._collect(child, parent_func)

    def _mark(self, func, origin: str, static: Set[str]) -> None:
        traced = (set(_param_names(func)) - static
                  - _default_static_params(func))
        ctxt = self.contexts.get(func)
        if ctxt is None:
            self.contexts[func] = TracedContext(func, origin, traced)
        else:
            ctxt.traced_params |= traced

    # -- direct jit ---------------------------------------------------------

    def _find_direct_jit(self) -> None:
        for func in self.functions:
            for deco in func.decorator_list:
                static = self._jit_static_of(deco, func)
                if static is not None:
                    self._mark(func, "@jax.jit", static)
        # g = jax.jit(f, static_argnames=...) / functools.partial forms
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Assign):
                continue
            call = node.value
            if not isinstance(call, ast.Call):
                continue
            static = self._jit_call_static(call)
            if static is None:
                continue
            target = call.args[0] if call.args else None
            d = dotted(target) if target is not None else None
            if d is not None and len(d) == 1 and d[0] in self.by_name:
                self._mark(self.by_name[d[0]], "jax.jit(...)", static)

    def _jit_static_of(self, deco: ast.AST, func) -> Optional[Set[str]]:
        """Static params if `deco` makes `func` jitted, else None."""
        if _is_jit_ref(deco):
            return set()
        if isinstance(deco, ast.Call):
            if _is_jit_ref(deco.func):  # @jax.jit(static_argnames=...)
                return self._statics_from(deco, func)
            d = dotted(deco.func)
            if d is not None and d[-1] == "partial" and deco.args \
                    and _is_jit_ref(deco.args[0]):
                return self._statics_from(deco, func)
        return None

    def _jit_call_static(self, call: ast.Call) -> Optional[Set[str]]:
        """Static params if `call` is jax.jit(f, ...) or partial(jax.jit,
        f-less, ...) applied later — else None."""
        if _is_jit_ref(call.func):
            return self._statics_from(call, None)
        d = dotted(call.func)
        if d is not None and d[-1] == "partial" and call.args \
                and _is_jit_ref(call.args[0]):
            return self._statics_from(call, None)
        return None

    def _statics_from(self, call: ast.Call, func) -> Set[str]:
        static = _parse_static_argnames(call)
        if func is not None:
            names = _param_names(func)
            for i in _parse_static_argnums(call):
                if i < len(names):
                    static.add(names[i])
        return static

    # -- combinator bodies --------------------------------------------------

    def _find_combinator_bodies(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func)
            if d is None or d[-1] not in _COMBINATORS:
                continue
            if len(d) >= 2 and d[-2] not in {"lax", "jax"}:
                continue  # e.g. some_dict.map(...)
            if len(d) == 1 and d[0] not in {"vmap", "grad", "scan", "cond",
                                            "switch"}:
                continue
            for idx in _COMBINATORS[d[-1]]:
                if idx >= len(node.args):
                    continue
                arg = node.args[idx]
                refs = [arg]
                # lax.switch takes a *sequence* of branch callables
                if isinstance(arg, (ast.Tuple, ast.List)):
                    refs = list(arg.elts)
                for ref in refs:
                    rd = dotted(ref)
                    if rd is not None and len(rd) == 1 \
                            and rd[0] in self.by_name:
                        self._mark(self.by_name[rd[0]],
                                   f"lax.{d[-1]} body", set())

    # -- entry points + call propagation ------------------------------------

    def _seed_entry_points(self) -> None:
        for name, static in TRACED_ENTRY_POINTS.items():
            func = self.by_name.get(name)
            if func is not None:
                self._mark(func, "known scan-body call site", set(static))

    def _propagate_calls(self) -> None:
        from repro.analysis.tracedness import traced_names_at_calls

        for _ in range(8):  # fixpoint (module call graphs are shallow)
            changed = False
            for func, ctxt in list(self.contexts.items()):
                for call, traced_args in traced_names_at_calls(
                        func, ctxt.traced_params):
                    d = dotted(call.func)
                    if d is None or len(d) != 1:
                        continue
                    callee = self.by_name.get(d[0])
                    if callee is None or callee is func:
                        continue
                    bound = self._bind(callee, call, traced_args)
                    if not bound:
                        continue
                    prev = self.contexts.get(callee)
                    before = set(prev.traced_params) if prev else None
                    self._mark(callee, f"called from {func.name}",
                               set(_param_names(callee)) - bound)
                    after = self.contexts[callee].traced_params
                    if before != after:
                        changed = True
            if not changed:
                return

    def _bind(self, callee, call: ast.Call, traced_args) -> Set[str]:
        """Param names of `callee` receiving traced arguments at `call`.

        `traced_args` maps id(arg-node) -> bool (argument expression
        references a traced name at the call site)."""
        names = _param_names(callee)
        a = callee.args
        pos_names = [p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
        traced: Set[str] = set()
        for i, arg in enumerate(call.args):
            if isinstance(arg, ast.Starred):
                break
            if i < len(pos_names) and traced_args.get(id(arg), False):
                traced.add(pos_names[i])
        for kw in call.keywords:
            if kw.arg is not None and kw.arg in names \
                    and traced_args.get(id(kw.value), False):
                traced.add(kw.arg)
        return traced

    # -- public -------------------------------------------------------------

    def traced_contexts(self) -> Iterator[TracedContext]:
        return iter(self.contexts.values())
