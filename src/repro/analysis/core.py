"""Rule engine: findings, reasoned suppressions, file walking, registry.

Everything here is plain stdlib — the analyzer must run in environments
without jax (the CI lint job, pre-commit hooks, editors).

Suppression grammar (one comment, one or more entries)::

    # repro-lint: disable=RULE-ID(reason text)
    # repro-lint: disable=RULE-A(why a), RULE-B(why b)
    # repro-lint: disable-next-line=RULE-ID(reason)
    # repro-lint: disable-file=RULE-ID(reason)

A trailing comment suppresses its own line; a comment-only line
suppresses the line below it (so long suppressions don't force long
code lines); ``disable-file`` suppresses the whole file. The reason is
mandatory: a bare ``disable=RULE-ID`` suppresses nothing and raises
SUPPRESS-NO-REASON at that line — the policy is that every silenced
finding documents *why* it is safe.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
import tokenize
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Tuple

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    severity: str = "error"

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} [{self.severity}] {self.message}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    """A registered rule: `check(SourceFile) -> iterable of Finding`."""

    id: str
    description: str
    check: Callable[["SourceFile"], Iterable[Finding]]


RULES: Dict[str, Rule] = {}

# Rule-ID grammar shared by the registry and the suppression parser.
_RULE_ID_RE = re.compile(r"^[A-Z][A-Z0-9]*(-[A-Z0-9]+)*$")
_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(?P<kind>disable(?:-next-line|-file)?)\s*=\s*"
    r"(?P<entries>.+?)\s*$")
# An entry is RULE-ID(reason). Reasons may contain commas (entries are
# matched by span, not split on separators) but not parentheses.
_ENTRY_RE = re.compile(r"(?P<rule>[A-Z][A-Z0-9-]*)\s*\((?P<reason>[^()]*)\)")
_BARE_ID_RE = re.compile(r"[A-Z][A-Z0-9-]*")

SUPPRESS_NO_REASON = "SUPPRESS-NO-REASON"
PARSE_ERROR = "PARSE-ERROR"


def register_rule(rule_id: str, description: str):
    """Class/function decorator adding a rule to the global registry.

    Accepts either a callable ``check(source_file)`` or a class with a
    ``check(self, source_file)`` method (instantiated once).
    """
    if not _RULE_ID_RE.match(rule_id):
        raise ValueError(f"rule id {rule_id!r} must be UPPER-KEBAB-CASE")

    def deco(obj):
        check = obj().check if isinstance(obj, type) else obj
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = Rule(rule_id, description, check)
        return obj

    return deco


@dataclasses.dataclass
class _Suppression:
    rule: str
    line: int  # line the suppression applies to (0 = whole file)
    reason: str
    used: bool = False


class SourceFile:
    """One parsed module: source text, AST, and its suppression table."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree: Optional[ast.Module] = None
        self.parse_failure: Optional[Finding] = None
        try:
            self.tree = ast.parse(text, filename=path)
        except SyntaxError as e:
            self.parse_failure = Finding(
                PARSE_ERROR, path, e.lineno or 1, e.offset or 0,
                f"syntax error: {e.msg}")
        self.suppressions: List[_Suppression] = []
        self.malformed: List[Finding] = []
        self._scan_suppressions()

    # -- suppression handling ------------------------------------------------

    def _scan_suppressions(self) -> None:
        for comment, lineno, comment_only in _iter_comments(self.text):
            m = _SUPPRESS_RE.search(comment)
            if not m:
                continue
            kind = m.group("kind")
            if kind == "disable-file":
                target = 0
            elif kind == "disable-next-line" or comment_only:
                target = lineno + 1
            else:
                target = lineno
            entries = m.group("entries")
            for em in _ENTRY_RE.finditer(entries):
                reason = em.group("reason").strip()
                if not reason:
                    self.malformed.append(Finding(
                        SUPPRESS_NO_REASON, self.path, lineno, 0,
                        f"suppression {em.group('rule')!r} carries no "
                        "reason; write disable=RULE-ID(why this is safe)"))
                    continue
                self.suppressions.append(
                    _Suppression(em.group("rule"), target, reason))
            # rule ids left over once reasoned entries are cut out are
            # bare `disable=RULE-ID` suppressions: rejected, not honored
            for bare in _BARE_ID_RE.finditer(_ENTRY_RE.sub("", entries)):
                self.malformed.append(Finding(
                    SUPPRESS_NO_REASON, self.path, lineno, 0,
                    f"suppression {bare.group(0)!r} carries no reason; "
                    "write disable=RULE-ID(why this is safe)"))

    def is_suppressed(self, finding: Finding) -> bool:
        for s in self.suppressions:
            if s.rule != finding.rule:
                continue
            if s.line == 0 or s.line == finding.line:
                s.used = True
                return True
        return False

    # -- convenience accessors for rules ------------------------------------

    def line_at(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def finding(self, rule: str, node, message: str,
                severity: str = "error") -> Finding:
        return Finding(rule, self.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message, severity)


def _iter_comments(text: str) -> Iterator[Tuple[str, int, bool]]:
    """Yield ``(comment, lineno, is_comment_only_line)`` via tokenize
    (robust against '#' inside string literals)."""
    import io

    try:
        tokens = tokenize.generate_tokens(io.StringIO(text).readline)
        for tok in tokens:
            if tok.type == tokenize.COMMENT:
                line = tok.line.strip()
                yield tok.string, tok.start[0], line.startswith("#")
    except (tokenize.TokenError, IndentationError, SyntaxError):
        # the PARSE-ERROR finding covers unparseable files
        return


# -- file walking and the analysis driver -----------------------------------

_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache",
              ".hypothesis", "node_modules", ".venv", "venv"}


def iter_python_files(paths: Iterable[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs if d not in _SKIP_DIRS)
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def analyze_file(path: str, rules: Optional[Iterable[Rule]] = None,
                 text: Optional[str] = None) -> List[Finding]:
    if text is None:
        with open(path, "r", encoding="utf-8") as fh:
            text = fh.read()
    src = SourceFile(path, text)
    findings: List[Finding] = list(src.malformed)
    if src.parse_failure is not None:
        return findings + [src.parse_failure]
    for rule in (RULES.values() if rules is None else rules):
        for f in rule.check(src):
            if not src.is_suppressed(f):
                findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def analyze_paths(paths: Iterable[str],
                  rules: Optional[Iterable[Rule]] = None
                  ) -> Tuple[List[Finding], int]:
    """Run `rules` (default: all registered) over every .py file under
    `paths`. Returns ``(findings, files_scanned)``."""
    findings: List[Finding] = []
    n = 0
    for path in iter_python_files(paths):
        n += 1
        findings.extend(analyze_file(path, rules))
    return findings, n


def report_json(findings: List[Finding], files_scanned: int) -> str:
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return json.dumps({
        "version": 1,
        "files_scanned": files_scanned,
        "rules": sorted(RULES),
        "counts": dict(sorted(counts.items())),
        "findings": [f.to_json() for f in findings],
    }, indent=2)
