"""CLI: ``python -m repro.analysis [paths...] [--strict] [--json FILE]``.

Exit codes: 0 clean (warnings allowed unless --strict), 1 findings,
2 usage error.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.analysis.core import RULES, analyze_paths, report_json


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX-aware static analyzer for this repo: RNG "
        "discipline, trace safety, recompile hazards, plane contracts.")
    parser.add_argument("paths", nargs="*", default=["src", "tests"],
                        help="files or directories (default: src tests)")
    parser.add_argument("--strict", action="store_true",
                        help="warnings fail too (the CI gate)")
    parser.add_argument("--json", metavar="FILE", default=None,
                        help="write the machine-readable report here "
                        "('-' for stdout)")
    parser.add_argument("--select", metavar="RULES", default=None,
                        help="comma-separated rule ids to run")
    parser.add_argument("--ignore", metavar="RULES", default=None,
                        help="comma-separated rule ids to skip")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        width = max(len(r) for r in RULES)
        for rid in sorted(RULES):
            print(f"{rid:<{width}}  {RULES[rid].description}")
        return 0

    rules = dict(RULES)
    if args.select:
        wanted = {r.strip() for r in args.select.split(",") if r.strip()}
        unknown = wanted - set(rules)
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        rules = {k: v for k, v in rules.items() if k in wanted}
    if args.ignore:
        dropped = {r.strip() for r in args.ignore.split(",") if r.strip()}
        rules = {k: v for k, v in rules.items() if k not in dropped}

    findings, files_scanned = analyze_paths(args.paths,
                                            rules=list(rules.values()))

    # with --json -, stdout must stay a single JSON document for the
    # consumer; the human-readable report moves to stderr
    human = sys.stderr if args.json == "-" else sys.stdout
    if args.json:
        payload = report_json(findings, files_scanned)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w", encoding="utf-8") as fh:
                fh.write(payload + "\n")

    errors = [f for f in findings if f.severity == "error"]
    warnings = [f for f in findings if f.severity != "error"]
    for f in findings:
        print(f.format(), file=human)
    tail = (f"{files_scanned} files scanned; "
            f"{len(errors)} error(s), {len(warnings)} warning(s)")
    failed = bool(errors) or (args.strict and bool(warnings))
    print(("FAIL: " if failed else "OK: ") + tail, file=human)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
