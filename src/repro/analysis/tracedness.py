"""Forward traced-name propagation through one function body.

Given a function and the set of its traced params (from
`repro.analysis.jaxctx`), a single in-order pass tracks which local
names (may) hold traced values and emits *hazard records* along the
way:

    ("branch",    node, detail)  Python `if`/`while`/`assert`/ternary/
                                 `bool()` on a traced value — a
                                 TracerBoolConversionError under jit,
                                 or worse: silent trace-time
                                 specialization on one concrete value.
    ("host-sync", node, detail)  `float()`/`int()`/`.item()`/
                                 `.tolist()`/`np.asarray`/`print` on a
                                 traced value — forces a device->host
                                 transfer (an error inside jit; a
                                 silent pipeline stall in op-by-op
                                 code).

The pass is flow-ordered but intentionally simple: loops are walked
twice (to catch loop-carried tracedness), `if` branches are walked
independently and their outcomes unioned, and nested `def`s are walked
with the enclosing traced set added (a closure defined under trace
captures tracers). Staticness exemptions live in `jaxctx` — see its
docstring for the full list.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set, Tuple

from repro.analysis.jaxctx import (
    STATIC_ATTRS,
    STRUCTURAL_CALLS,
    _default_static_params,
    _param_names,
    dotted,
)

_NP_ROOTS = {"np", "numpy", "onp"}
_HOST_SYNC_METHODS = {"item", "tolist", "block_until_ready"}


class TraceWalker:
    def __init__(self, func: ast.FunctionDef, traced_params: Set[str]):
        self.func = func
        self.hazards: List[Tuple[str, ast.AST, str]] = []
        self.calls: List[Tuple[ast.Call, Dict[int, bool]]] = []
        static = _default_static_params(func)
        self.traced: Set[str] = set(traced_params) - static

    def run(self) -> "TraceWalker":
        self.visit_block(self.func.body)
        return self

    # -- expressions --------------------------------------------------------

    def is_traced(self, node: ast.AST) -> bool:
        """Does evaluating `node` touch a traced value? (Also records
        hazards and call-argument tracedness as side effects.)"""
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Attribute):
            d = dotted(node)
            if d is not None:
                if d[0] not in self.traced:
                    return False
                return not any(part in STATIC_ATTRS for part in d[1:])
            return self.is_traced(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_traced(node.value) | self.is_traced(node.slice)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False  # identity tests are structural
            t = self.is_traced(node.left)
            for c in node.comparators:
                t |= self.is_traced(c)
            return t
        if isinstance(node, ast.Call):
            return self._visit_call(node)
        if isinstance(node, ast.IfExp):
            if self.is_traced(node.test):
                self.hazards.append((
                    "branch", node.test,
                    "ternary `a if cond else b` on a traced value"))
            return self.is_traced(node.body) | self.is_traced(node.orelse)
        if isinstance(node, ast.Lambda):
            return False  # a lambda *expression* is a static callable
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            t = False
            for gen in node.generators:
                t |= self.is_traced(gen.iter)
                for cond in gen.ifs:
                    t |= self.is_traced(cond)
            if isinstance(node, ast.DictComp):
                t |= self.is_traced(node.key) | self.is_traced(node.value)
            else:
                t |= self.is_traced(node.elt)
            return t
        t = False
        for child in ast.iter_child_nodes(node):
            t |= self.is_traced(child)
        return t

    def _visit_call(self, node: ast.Call) -> bool:
        d = dotted(node.func)
        name = d[-1] if d else None
        root = d[0] if d else None

        arg_traced: Dict[int, bool] = {}
        any_traced = False
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            inner = arg.value if isinstance(arg, ast.Starred) else arg
            t = self.is_traced(inner)
            arg_traced[id(arg)] = t
            any_traced |= t
        if d is not None and len(d) == 1:
            self.calls.append((node, arg_traced))

        if name in STRUCTURAL_CALLS and root == name:
            return False  # isinstance/len/hasattr/...: structural reads

        first = node.args[0] if node.args else None
        first_traced = first is not None and arg_traced.get(id(first), False)
        if root == name == "bool" and first_traced:
            self.hazards.append((
                "branch", node, "bool() forces a traced value to a Python "
                "bool (concretization error under jit)"))
        elif root == name in {"float", "int"} and first_traced:
            self.hazards.append((
                "host-sync", node,
                f"{name}() on a traced value forces a device->host sync"))
        elif root == name == "print" and any_traced:
            self.hazards.append((
                "host-sync", node, "print() on traced values syncs the "
                "device; use jax.debug.print inside jit"))
        elif d is not None and len(d) >= 2 and root in _NP_ROOTS \
                and name in {"asarray", "array"} and first_traced:
            self.hazards.append((
                "host-sync", node,
                f"{root}.{name}() on a traced value pulls it to host "
                "memory; use jnp inside compiled code"))
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in _HOST_SYNC_METHODS \
                and self.is_traced(node.func.value):
            self.hazards.append((
                "host-sync", node,
                f".{node.func.attr}() on a traced value forces a "
                "device->host sync"))
        # a method call on a traced receiver returns a traced value
        # (x.sum(), state._replace(...)); a bare Name callee does not —
        # calling `f` doesn't make the result traced unless its args are
        recv = (not isinstance(node.func, ast.Name)
                and self.is_traced(node.func))
        return any_traced | recv

    # -- statements ---------------------------------------------------------

    def visit_block(self, stmts: Iterable[ast.stmt]) -> None:
        for stmt in stmts:
            self.visit_stmt(stmt)

    def _assign_target(self, target: ast.AST, traced: bool) -> None:
        if isinstance(target, ast.Name):
            if traced:
                self.traced.add(target.id)
            else:
                self.traced.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                inner = elt.value if isinstance(elt, ast.Starred) else elt
                self._assign_target(inner, traced)
        # attribute/subscript stores don't (re)bind local names

    def _isinstance_narrowed(self, test: ast.AST) -> Set[str]:
        """Names proven non-traced inside an `if isinstance(x, ...)` body
        (conjunctions included)."""
        names: Set[str] = set()
        tests = test.values if isinstance(test, ast.BoolOp) \
            and isinstance(test.op, ast.And) else [test]
        for t in tests:
            if isinstance(t, ast.Call):
                d = dotted(t.func)
                if d == ("isinstance",) and t.args \
                        and isinstance(t.args[0], ast.Name):
                    names.add(t.args[0].id)
        return names

    def visit_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            t = self.is_traced(stmt.value)
            for target in stmt.targets:
                self._assign_target(target, t)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._assign_target(stmt.target, self.is_traced(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            t = self.is_traced(stmt.value)
            if isinstance(stmt.target, ast.Name):
                if t:
                    self.traced.add(stmt.target.id)
        elif isinstance(stmt, ast.If):
            if self.is_traced(stmt.test):
                self.hazards.append((
                    "branch", stmt.test,
                    "Python `if` on a traced value (trace-time "
                    "concretization; use lax.cond / jnp.where)"))
            narrowed = self._isinstance_narrowed(stmt.test)
            saved = set(self.traced)
            self.traced -= narrowed
            self.visit_block(stmt.body)
            after_body = set(self.traced)
            self.traced = set(saved)
            self.visit_block(stmt.orelse)
            self.traced |= after_body
        elif isinstance(stmt, ast.While):
            if self.is_traced(stmt.test):
                self.hazards.append((
                    "branch", stmt.test,
                    "Python `while` on a traced value (use lax.while_loop)"))
            self.visit_block(stmt.body)  # twice: loop-carried tracedness
            self.visit_block(stmt.body)
            self.visit_block(stmt.orelse)
        elif isinstance(stmt, ast.For):
            t = self.is_traced(stmt.iter)
            self._assign_target(stmt.target, t)
            self.visit_block(stmt.body)
            self.visit_block(stmt.body)  # twice: loop-carried tracedness
            self.visit_block(stmt.orelse)
        elif isinstance(stmt, ast.Assert):
            if self.is_traced(stmt.test):
                self.hazards.append((
                    "branch", stmt.test,
                    "`assert` on a traced value (concretization under jit; "
                    "use checkify or move the check host-side)"))
            if stmt.msg is not None:
                self.is_traced(stmt.msg)
        elif isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                self.is_traced(stmt.value)
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                self.is_traced(item.context_expr)
            self.visit_block(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.visit_block(stmt.body)
            for h in stmt.handlers:
                self.visit_block(h.body)
            self.visit_block(stmt.orelse)
            self.visit_block(stmt.finalbody)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a def under trace closes over tracers: walk it with the
            # enclosing traced set plus its own (non-static) params
            inner = TraceWalker(stmt, set(_param_names(stmt)))
            inner.traced |= self.traced
            inner.run()
            self.hazards.extend(inner.hazards)
            self.calls.extend(inner.calls)
            self.traced.discard(stmt.name)
        elif isinstance(stmt, (ast.Raise, ast.Delete, ast.Global,
                               ast.Nonlocal, ast.Pass, ast.Break,
                               ast.Continue, ast.Import, ast.ImportFrom)):
            return
        else:
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.stmt):
                    self.visit_stmt(child)
                elif isinstance(child, ast.expr):
                    self.is_traced(child)


def analyze_function(func: ast.FunctionDef, traced_params: Set[str]
                     ) -> TraceWalker:
    return TraceWalker(func, traced_params).run()


def traced_names_at_calls(func: ast.FunctionDef, traced_params: Set[str]):
    """(call, {id(arg) -> traced}) pairs for in-module propagation."""
    return analyze_function(func, traced_params).calls
