"""The `Task` abstraction: pluggable (model x optimizer x dataset) workloads.

DRACO is a statement about training *neural networks* over asynchronous
row-stochastic networks, but a protocol step only ever touches the
workload through four narrow interfaces: a loss to differentiate, a
federated dataset to draw batches from, an update rule to apply per
local batch, and an eval metric. A `Task` bundles exactly those —

  - **model**: `init_params(key)` -> single-client param pytree, plus a
    `loss_fn(params, x, y)` closed over the architecture (static, so it
    is a stable jit key — tasks are cached singletons);
  - **data**: `make_data(key, num_clients)` -> `((xs, ys), (ex, ey))`
    federated train shards with a leading client axis + held-out eval;
  - **local optimizer**: `make_optimizer(lr)` -> a `repro.optim`
    `Optimizer` whose per-client state rides the flat parameter plane
    (`(N, Dopt)` next to the `(N, Dflat)` payloads — see
    `repro.core.protocol.task_local_updates`);
  - **metric**: `eval_fn(params, ex, ey)` -> scalar, named by
    `metric_name` ("accuracy", "perplexity") in the `SimTrace`;
  - **cost**: `grad_cost`, the relative FLOP price of one local
    gradient event, consumed by `repro.api.steps_for_budget` so
    compute-matched comparisons equalize FLOPs, not event counts.

Tasks register with `@register_task("name")` — the same string-keyed
idiom as the algorithm and scenario registries — and are built via
`get_task(name, **knobs)`. Builds are cached on `(name, knobs)`:
`get_task` returns the *same* `Task` object for the same arguments, so
using a task as a static jit key never recompiles across calls.

Legacy shim: everything downstream also accepts a bare loss callable
where a `Task` is expected — dispatch is duck-typed on `loss_fn`
(`repro.core.protocol.local_step`, `SimContext.loss_fn`), so the
pre-task `simulate(..., loss_fn=...)` call sites keep working
bit-for-bit through the exact seed compiled graph. `as_task` /
`loss_of` are convenience converters for external code that wants one
uniform representation; the hot path never wraps.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro import optim


@dataclass(frozen=True)
class Task:
    """Immutable (model x optimizer x dataset) bundle; a static jit key.

    Frozen + field-identity equality: two `get_task` calls with the same
    arguments return the same cached instance, so jit caches keyed on
    the task are stable.
    """

    name: str
    init_params: Callable  # key -> single-client param pytree
    loss_fn: Callable  # (params, x, y) -> scalar (differentiated per batch)
    eval_fn: Callable  # (params, ex, ey) -> scalar metric
    make_data: Callable  # (key, num_clients) -> ((xs, ys), (ex, ey))
    metric_name: str = "accuracy"
    opt_name: str = "sgd"  # repro.optim factory name
    schedule: str = "constant"  # lr schedule family
    opt_kwargs: Tuple[Tuple[str, Any], ...] = ()  # (beta, b1, ...) frozen
    schedule_kwargs: Tuple[Tuple[str, Any], ...] = ()
    grad_cost: float = 1.0  # relative FLOPs of one local gradient event
    # optimizer hyperparameters the sweep engine may re-bind as traced
    # scalars (threaded into make_optimizer); today that is the lr that
    # seeds the schedule
    sweepable: Tuple[str, ...] = ("lr",)

    def make_optimizer(self, lr) -> optim.Optimizer:
        """Build the local update rule with `lr` seeding the schedule.

        `lr` may be a python float (the static-config path) or a traced
        f32 scalar (the sweep engine's lr axis) — every schedule closes
        over it without shape commitments.
        """
        sched_fn = _SCHEDULES[self.schedule](lr, **dict(self.schedule_kwargs))
        return _OPTIMIZERS[self.opt_name](sched_fn, **dict(self.opt_kwargs))

    def setup(self, key, num_clients: int):
        """Convenience builder: `(params0, train, eval_data)` from one key."""
        kp, kd = jax.random.split(key)
        train, eval_data = self.make_data(kd, num_clients)
        return self.init_params(kp), train, eval_data

    def with_optimizer(self, opt_name: str, schedule: str = None,
                       schedule_kwargs: dict = None,
                       **opt_kwargs) -> "Task":
        """The same workload under a different local update rule.

        `schedule_kwargs` carries the schedule family's knobs (e.g.
        ``schedule="cosine", schedule_kwargs={"total_steps": 600}``).
        Kwargs follow their family: changing the optimizer/schedule
        family without passing new kwargs clears the old family's
        kwargs (they would not typecheck); keeping the family keeps
        them.
        """
        if opt_name not in _OPTIMIZERS:
            raise KeyError(
                f"unknown optimizer {opt_name!r}; known: {sorted(_OPTIMIZERS)}")
        if schedule is not None and schedule not in _SCHEDULES:
            raise KeyError(
                f"unknown schedule {schedule!r}; known: {sorted(_SCHEDULES)}")
        if opt_kwargs:
            opt_kw = tuple(sorted(opt_kwargs.items()))
        else:
            opt_kw = self.opt_kwargs if opt_name == self.opt_name else ()
        if schedule_kwargs is not None:
            sched_kw = tuple(sorted(schedule_kwargs.items()))
        elif schedule is None or schedule == self.schedule:
            sched_kw = self.schedule_kwargs  # family kept -> kwargs kept
        else:
            sched_kw = ()
        return replace(
            self, opt_name=opt_name,
            schedule=self.schedule if schedule is None else schedule,
            opt_kwargs=opt_kw,
            schedule_kwargs=sched_kw)

    def __repr__(self):
        return (f"Task({self.name!r}, opt={self.opt_name}/{self.schedule}, "
                f"metric={self.metric_name}, grad_cost={self.grad_cost:.3g})")


_OPTIMIZERS = {
    "sgd": lambda sched: optim.sgd(sched),
    "momentum": optim.momentum,
    "adamw": optim.adamw,
}

_SCHEDULES = {
    "constant": lambda lr: optim.constant_schedule(lr),
    "cosine": optim.cosine_schedule,
    "warmup-cosine": optim.warmup_cosine,
}


def is_task(obj) -> bool:
    """Duck-typed check used by the protocol layer (no import cycle)."""
    return isinstance(obj, Task)


def as_task(loss_or_task, name: str = "<legacy-loss>") -> Optional[Task]:
    """Legacy shim: wrap a bare loss callable into a plain-SGD task.

    Cached on the callable, so the wrapper — and therefore every jit
    key derived from it — is stable across calls. `Task`s and `None`
    pass through unchanged.
    """
    if loss_or_task is None or is_task(loss_or_task):
        return loss_or_task
    if not callable(loss_or_task):
        raise TypeError(
            f"expected a Task, a loss callable or None; got {loss_or_task!r}")
    try:
        return _WRAPPED[loss_or_task]
    except KeyError:
        pass

    def _no_data(key, num_clients):
        raise NotImplementedError(
            "a legacy bare-loss task has no dataset builder; pass data= "
            "explicitly or use a registered task")

    t = Task(name=name, init_params=_no_init, loss_fn=loss_or_task,
             eval_fn=_no_eval, make_data=_no_data)
    _WRAPPED[loss_or_task] = t
    return t


def _no_init(key):
    raise NotImplementedError(
        "a legacy bare-loss task has no model builder; pass params0=")


def _no_eval(params, ex, ey):
    raise NotImplementedError(
        "a legacy bare-loss task has no eval metric; pass eval_fn=")


_WRAPPED: Dict[Callable, Task] = {}


def loss_of(task_or_loss):
    """The bare loss callable of either representation (legacy accessor)."""
    if is_task(task_or_loss):
        return task_or_loss.loss_fn
    return task_or_loss


def opt_width(task, params0) -> int:
    """Per-client flat width Dopt of the task's optimizer state.

    Probed with `jax.eval_shape` on the single-client pytree — no real
    compute, exact for any optimizer whose state is a pytree of arrays
    (sgd -> 0, momentum -> Dflat, adamw -> 2*Dflat + 1: m, v and its
    per-client bias-correction counter).
    """
    if task is None or not is_task(task):
        return 0
    opt = task.make_optimizer(0.0)
    shapes = jax.eval_shape(opt.init, params0)
    return int(sum(np.prod(l.shape, dtype=np.int64)
                   for l in jax.tree_util.tree_leaves(shapes)))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_BUILDERS: Dict[str, Callable[..., Task]] = {}
_CACHE: Dict[Tuple, Task] = {}


def register_task(name: str):
    """Decorator: register a task *builder* under `name`.

    The builder is called lazily by `get_task(name, **knobs)` and its
    result cached per knob set, so tasks are singletons.
    """

    def deco(fn):
        _BUILDERS[name] = fn
        return fn

    return deco


def _freeze(v):
    """Hashable canonical form of a builder kwarg (dicts/lists allowed:
    ``get_task("mlp", hidden=[64, 64], opt_kwargs={"beta": 0.95})``)."""
    if isinstance(v, dict):
        return ("<dict>",) + tuple(sorted((k, _freeze(x))
                                          for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    return v


def get_task(name: str, **kwargs) -> Task:
    """Resolve (and memoize) a registered task; `Task`s pass through."""
    if is_task(name):
        return name
    try:
        builder = _BUILDERS[name]
    except KeyError:
        raise KeyError(
            f"unknown task {name!r}; registered: {sorted(_BUILDERS)}"
        ) from None
    cache_key = (name, tuple(sorted((k, _freeze(v))
                                    for k, v in kwargs.items())))
    if cache_key not in _CACHE:
        _CACHE[cache_key] = builder(**kwargs)
    return _CACHE[cache_key]


def list_tasks() -> Tuple[str, ...]:
    return tuple(sorted(_BUILDERS))
