"""`repro.tasks` — first-class (model x optimizer x dataset) workloads.

    from repro.tasks import get_task, list_tasks

    task = get_task("mlp", optimizer="adamw")
    state, trace = simulate("draco", cfg, task=task, num_steps=600,
                            key=key, eval_every=100)

See `repro.tasks.base` for the `Task` contract and
`repro.tasks.zoo` for the built-in workloads
(linear-softmax / mlp / small-cnn / tiny-lm).
"""
from repro.tasks.base import (
    Task,
    as_task,
    get_task,
    is_task,
    list_tasks,
    loss_of,
    opt_width,
    register_task,
)

# importing the module registers the built-in tasks
from repro.tasks import zoo  # noqa: F401

__all__ = [
    "Task",
    "as_task",
    "get_task",
    "is_task",
    "list_tasks",
    "loss_of",
    "opt_width",
    "register_task",
    "zoo",
]
