"""The built-in task zoo: four workloads spanning the model registry.

  - ``linear-softmax`` — single dense softmax on the Gaussian-mixture
    classification data: exactly the workload the simulator hard-coded
    before the task layer, kept bit-for-bit (the default task).
  - ``mlp`` — the paper-style 2-hidden-layer relu MLP on the same
    non-IID mixture (the fig3 EMNIST/Poker stand-in family).
  - ``small-cnn`` — a 2-conv + pooled-head network over the mixture
    reshaped as single-channel images (the paper's 0.57 MB CNN shape).
  - ``tiny-lm`` — a one-block pre-norm transformer decoder (RoPE
    attention + SwiGLU MLP from `repro.models.layers`) over the
    deterministic synthetic token streams; metric is perplexity.

Every builder returns a `Task` with plain SGD + constant schedule as
the local update rule; swap the optimizer with
``get_task("mlp", optimizer="adamw")`` or ``task.with_optimizer(...)``
— optimizer state lands on the flat plane automatically.

`grad_cost` is the relative FLOP price of one local gradient event per
sample: ``6 * n_params`` (fwd + ~2x bwd, 2 FLOPs per MAC), times
``seq_len`` for the LM (every sample is a full sequence), in MFLOPs.
`repro.api.steps_for_budget` uses it so budget-matched runs equalize
FLOPs across tasks, not just event counts.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, cross_entropy, dense_init, init_mlp
from repro.models.layers import mlp as swiglu_mlp
from repro.models.layers import rms_norm
from repro.tasks.base import Task, register_task


def _param_count(init_params) -> int:
    shapes = jax.eval_shape(init_params, jax.random.PRNGKey(0))
    return int(sum(np.prod(l.shape, dtype=np.int64)
                   for l in jax.tree_util.tree_leaves(shapes)))


def _mflops_per_grad(n_params: int, tokens: int = 1) -> float:
    return 6.0 * n_params * tokens / 1e6


def _opt_variant(base: Task, optimizer, schedule, opt_kwargs,
                 schedule_kwargs) -> Task:
    """Optimizer variant of a cached base workload.

    Every spelling of the same workload shares ONE base task per knob
    set (the `@lru_cache`d `_*_base` builders below), so
    ``get_task("mlp", optimizer="adamw")`` and
    ``get_task("mlp").with_optimizer("adamw")`` produce *equal* tasks —
    same loss/eval/data closures, hence one static jit key and no
    spurious ctx-task mismatches.
    """
    return base.with_optimizer(optimizer, schedule=schedule,
                               schedule_kwargs=schedule_kwargs,
                               **(opt_kwargs or {}))


# ---------------------------------------------------------------------------
# Classification family (Gaussian mixture, Dirichlet non-IID shards)
# ---------------------------------------------------------------------------


def _classification_data(key, num_clients, *, input_dim, num_classes,
                         per_client, alpha, noise, test_size):
    from repro.data.synthetic import federated_classification

    return federated_classification(
        key, num_clients, input_dim=input_dim, num_classes=num_classes,
        per_client=per_client, alpha=alpha, test_size=test_size, noise=noise)


@lru_cache(maxsize=None)
def _mlp_base(name, hidden, input_dim, num_classes, per_client, alpha,
              noise) -> Task:
    from repro.data.synthetic import make_mlp

    # apply/loss/accuracy close over dims only, not over the params the
    # throwaway key produces — one build gives the stable jit-key closures
    _, _, loss, acc = make_mlp(jax.random.PRNGKey(0), input_dim, hidden,
                               num_classes)
    init = partial(_mlp_init, input_dim=input_dim, hidden=hidden,
                   num_classes=num_classes)
    return Task(
        name=name, init_params=init, loss_fn=loss, eval_fn=acc,
        make_data=partial(_classification_data, input_dim=input_dim,
                          num_classes=num_classes, per_client=per_client,
                          alpha=alpha, noise=noise, test_size=2000),
        metric_name="accuracy",
        grad_cost=_mflops_per_grad(_param_count(init)),
    )


def _mlp_init(key, *, input_dim, hidden, num_classes):
    from repro.data.synthetic import make_mlp

    return make_mlp(key, input_dim, hidden, num_classes)[0]


@register_task("linear-softmax")
def build_linear_softmax(input_dim: int = 16, num_classes: int = 5,
                         per_client: int = 256, alpha: float = 0.5,
                         noise: float = 0.6, optimizer: str = "sgd",
                         schedule: str = "constant", opt_kwargs=None,
                         schedule_kwargs=None) -> Task:
    """Single dense layer + softmax CE — the pre-task-layer default
    workload, bit-for-bit (tests/test_tasks.py pins it)."""
    base = _mlp_base("linear-softmax", (), input_dim, num_classes,
                     per_client, alpha, noise)
    return _opt_variant(base, optimizer, schedule, opt_kwargs,
                        schedule_kwargs)


@register_task("mlp")
def build_mlp(input_dim: int = 16, num_classes: int = 5,
              hidden: tuple = (32, 32), per_client: int = 256,
              alpha: float = 0.5, noise: float = 0.6,
              optimizer: str = "sgd", schedule: str = "constant",
              opt_kwargs=None, schedule_kwargs=None) -> Task:
    """Paper-style relu MLP (fig3's EMNIST/Poker stand-in family)."""
    base = _mlp_base("mlp", tuple(hidden), input_dim, num_classes,
                     per_client, alpha, noise)
    return _opt_variant(base, optimizer, schedule, opt_kwargs,
                        schedule_kwargs)


# ---------------------------------------------------------------------------
# small-cnn: 2 conv blocks + dense head over mixture "images"
# ---------------------------------------------------------------------------


def _cnn_init(key, *, side, channels, num_classes):
    c1, c2 = channels
    k1, k2, k3 = jax.random.split(key, 3)
    feat = (side // 4) * (side // 4) * c2
    return {
        "conv1": dense_init(k1, (3, 3, 1, c1), 9),
        "b1": jnp.zeros((c1,)),
        "conv2": dense_init(k2, (3, 3, c1, c2), 9 * c1),
        "b2": jnp.zeros((c2,)),
        "w_head": dense_init(k3, (feat, num_classes), feat),
        "b_head": jnp.zeros((num_classes,)),
    }


def _avg_pool2(x):
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))


def _cnn_apply(p, x, *, side):
    h = x.reshape(-1, side, side, 1)
    for w, b in ((p["conv1"], p["b1"]), (p["conv2"], p["b2"])):
        h = jax.lax.conv_general_dilated(
            h, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = _avg_pool2(jax.nn.relu(h + b))
    return h.reshape(h.shape[0], -1) @ p["w_head"] + p["b_head"]


@lru_cache(maxsize=None)
def _cnn_base(side, num_classes, channels, per_client, alpha, noise) -> Task:
    init = partial(_cnn_init, side=side, channels=channels,
                   num_classes=num_classes)
    apply = partial(_cnn_apply, side=side)

    def loss(params, x, y):
        return cross_entropy(apply(params, x), y)

    def accuracy(params, x, y):
        return (apply(params, x).argmax(-1) == y).mean()

    return Task(
        name="small-cnn", init_params=init, loss_fn=loss, eval_fn=accuracy,
        make_data=partial(_classification_data, input_dim=side * side,
                          num_classes=num_classes, per_client=per_client,
                          alpha=alpha, noise=noise, test_size=1000),
        metric_name="accuracy",
        # conv FLOPs dominate the tiny head: count them spatially
        # (params alone undercounts weight reuse by H*W)
        grad_cost=_mflops_per_grad(
            9 * 1 * channels[0] * side * side
            + 9 * channels[0] * channels[1] * (side // 2) * (side // 2)
            + (side // 4) * (side // 4) * channels[1] * num_classes),
    )


@register_task("small-cnn")
def build_small_cnn(side: int = 8, num_classes: int = 5,
                    channels: tuple = (8, 16), per_client: int = 256,
                    alpha: float = 0.5, noise: float = 0.6,
                    optimizer: str = "sgd", schedule: str = "constant",
                    opt_kwargs=None, schedule_kwargs=None) -> Task:
    """2-conv + pooled head over `side x side` single-channel mixture
    images (flat `(B, side*side)` inputs, reshaped inside apply — the
    data pipeline is shared with the dense classification tasks)."""
    if side % 4 != 0:
        raise ValueError(f"side must be divisible by 4 (two 2x2 pools), "
                         f"got {side}")
    base = _cnn_base(side, num_classes, tuple(channels), per_client, alpha,
                     noise)
    return _opt_variant(base, optimizer, schedule, opt_kwargs,
                        schedule_kwargs)


# ---------------------------------------------------------------------------
# tiny-lm: one-block pre-norm transformer decoder on synthetic tokens
# ---------------------------------------------------------------------------


def _lm_init(key, *, vocab, d_model, num_heads, d_ff):
    ke, kq, kk, kv, ko, km, kh = jax.random.split(key, 7)
    hd = d_model // num_heads
    return {
        "emb": dense_init(ke, (vocab, d_model), d_model),
        "ln1": jnp.zeros((d_model,)),
        "attn": {
            "wq": dense_init(kq, (d_model, num_heads * hd), d_model),
            "wk": dense_init(kk, (d_model, num_heads * hd), d_model),
            "wv": dense_init(kv, (d_model, num_heads * hd), d_model),
            "wo": dense_init(ko, (num_heads * hd, d_model), num_heads * hd),
        },
        "ln2": jnp.zeros((d_model,)),
        "mlp": init_mlp(km, d_model, d_ff, jnp.float32),
        "lnf": jnp.zeros((d_model,)),
        "head": dense_init(kh, (d_model, vocab), d_model),
    }


def _lm_apply(p, toks, *, num_heads, rope_theta=10_000.0, eps=1e-5):
    """toks (B, S) int32 -> logits (B, S, V); causal RoPE attention."""
    B, S = toks.shape
    d = p["emb"].shape[1]
    hd = d // num_heads
    h = p["emb"][toks]  # (B, S, d)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    a = rms_norm(h, p["ln1"], eps)
    q = apply_rope((a @ p["attn"]["wq"]).reshape(B, S, num_heads, hd),
                   pos, rope_theta)
    k = apply_rope((a @ p["attn"]["wk"]).reshape(B, S, num_heads, hd),
                   pos, rope_theta)
    v = (a @ p["attn"]["wv"]).reshape(B, S, num_heads, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(hd))
    causal = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(causal[None, None], scores, -1e30)
    out = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, axis=-1), v)
    h = h + out.reshape(B, S, d) @ p["attn"]["wo"]
    h = h + swiglu_mlp(p["mlp"], rms_norm(h, p["ln2"], eps))
    return rms_norm(h, p["lnf"], eps) @ p["head"]


def _lm_data(key, num_clients, *, per_client, seq_len, vocab, eval_size):
    from repro.data.synthetic import lm_token_batches

    kt, ke = jax.random.split(key)
    toks = lm_token_batches(kt, num_clients, per_client, seq_len + 1, vocab)
    ev = lm_token_batches(ke, 1, eval_size, seq_len + 1, vocab)[0]
    return (toks[..., :-1], toks[..., 1:]), (ev[:, :-1], ev[:, 1:])


@lru_cache(maxsize=None)
def _lm_base(vocab, d_model, num_heads, d_ff, seq_len, per_client,
             eval_size) -> Task:
    init = partial(_lm_init, vocab=vocab, d_model=d_model,
                   num_heads=num_heads, d_ff=d_ff)
    apply = partial(_lm_apply, num_heads=num_heads)

    def loss(params, x, y):
        return cross_entropy(apply(params, x), y)

    def perplexity(params, ex, ey):
        return jnp.exp(jnp.minimum(loss(params, ex, ey), 20.0))

    return Task(
        name="tiny-lm", init_params=init, loss_fn=loss, eval_fn=perplexity,
        make_data=partial(_lm_data, per_client=per_client, seq_len=seq_len,
                          vocab=vocab, eval_size=eval_size),
        metric_name="perplexity",
        grad_cost=_mflops_per_grad(_param_count(init), tokens=seq_len),
    )


@register_task("tiny-lm")
def build_tiny_lm(vocab: int = 64, d_model: int = 32, num_heads: int = 2,
                  d_ff: int = 64, seq_len: int = 16, per_client: int = 128,
                  eval_size: int = 64, optimizer: str = "sgd",
                  schedule: str = "constant", opt_kwargs=None,
                  schedule_kwargs=None) -> Task:
    """One-block pre-norm decoder (RoPE attention + SwiGLU from
    `repro.models.layers`) on the deterministic synthetic token streams.
    Metric: per-client perplexity on a held-out stream (lower is
    better); the grad cost scales with `seq_len` — every local batch
    sample is a full sequence."""
    if d_model % num_heads != 0:
        raise ValueError(f"d_model={d_model} not divisible by "
                         f"num_heads={num_heads}")
    base = _lm_base(vocab, d_model, num_heads, d_ff, seq_len, per_client,
                    eval_size)
    return _opt_variant(base, optimizer, schedule, opt_kwargs,
                        schedule_kwargs)
