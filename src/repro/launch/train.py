"""End-to-end DRACO trainer on a device mesh.

Trains an assigned architecture (usually a reduced variant on CPU; the
full config on a real mesh) with the production-plane DRACO window step:
per-client local grads, row-stochastic gossip mixing with per-window
event/Psi masks, periodic unification, checkpointing and eval.

Protocol-plane construction (gossip graph, row-stochastic Q, Metropolis
weights) goes through `repro.api.make_context`, the same context the
simulation driver uses, so the trainer and the paper-figure benchmarks
share one graph/channel setup path.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --reduced \
      --steps 200 --clients 4 --mesh 2x2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.api import make_context
from repro.configs.base import get_config, get_reduced
from repro.core import mixing
from repro.core.events import sample_event_masks
from repro.core.protocol import DracoConfig
from repro.launch import steps as steps_lib
from repro.models import model as M


def make_batches(key, cfg, n_clients: int, per_client: int, seq: int):
    """Synthetic LM token shards per client."""
    data = {}
    if cfg.embeds_in:
        data["embeds"] = jax.random.normal(
            key, (n_clients, per_client, seq, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
        data["labels"] = jax.random.randint(
            jax.random.fold_in(key, 1), (n_clients, per_client, seq), 0, cfg.vocab_size
        )
    else:
        data["tokens"] = jax.random.randint(
            key, (n_clients, per_client, seq), 0, cfg.vocab_size
        )
    if cfg.family == "vlm":
        data["cross_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 2),
            (n_clients, per_client, cfg.num_patch_tokens, cfg.d_model),
        ).astype(jnp.dtype(cfg.dtype))
    return data


def select_batch(data, idx, batch_per_client: int):
    n = next(iter(data.values())).shape[0]
    start = (idx * batch_per_client) % max(
        next(iter(data.values())).shape[1] - batch_per_client + 1, 1
    )
    return {k: jax.lax.dynamic_slice_in_dim(v, start, batch_per_client, axis=1)
            for k, v in data.items()}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch-per-client", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mix", default="dense", choices=["dense", "ring", "none"])
    ap.add_argument("--psi", type=int, default=0)
    ap.add_argument("--topology", default="cycle")
    ap.add_argument("--unify-every", type=int, default=50)
    ap.add_argument("--lambda-tx", type=float, default=1.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    n = args.clients
    key = jax.random.PRNGKey(args.seed)
    k_init, k_data, k_ev = jax.random.split(key, 3)
    k_graph = jax.random.fold_in(key, 3)  # keeps legacy k_* streams intact

    # mesh: use whatever devices exist, (data=n, model=rest) if possible
    n_dev = len(jax.devices())
    model_par = max(n_dev // n, 1)
    mesh = None
    if n_dev >= n * model_par and n * model_par > 1:
        mesh = jax.make_mesh((n, model_par), ("data", "model"))

    params0 = M.init_params(k_init, cfg)
    params = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p[None], (n,) + p.shape).copy(), params0
    )
    # protocol-plane context: graph + weights built once, same path the
    # unified simulation driver uses (repro.api)
    proto_cfg = DracoConfig(num_clients=n, topology=args.topology,
                            psi=args.psi, unify_period=args.unify_every,
                            lambda_tx=args.lambda_tx, channel=None)
    ctx = make_context(proto_cfg, graph_key=k_graph)
    q = ctx.q
    data = make_batches(k_data, cfg, n, per_client=8 * args.batch_per_client,
                        seq=args.seq)

    if mesh is not None:
        step_fn = steps_lib.make_train_step(cfg, mesh, lr=args.lr,
                                            mix_mode=args.mix, psi=args.psi)
        unify_fn = jax.jit(steps_lib.make_unify_step(cfg, mesh))
    else:
        # single-device fallback (pure data-path test)
        def step_fn(params, batch, q_eff):
            def client_loss(p_i, b_i):
                return M.lm_loss(p_i, cfg, b_i)

            loss, grads = jax.vmap(jax.value_and_grad(client_loss))(params, batch)
            delta = jax.tree_util.tree_map(lambda g: -args.lr * g, grads)
            add = mixing.mix_dense(q_eff, delta)
            new_params = jax.tree_util.tree_map(
                lambda p, a: p + a.astype(p.dtype), params, add)
            return new_params, loss.mean()

        unify_fn = jax.jit(steps_lib.make_unify_step(cfg, None))
    jit_step = jax.jit(step_fn)

    start = 0
    if args.ckpt_dir:
        latest = ckpt_lib.latest_step(args.ckpt_dir)
        if latest is not None:
            params = ckpt_lib.restore(args.ckpt_dir, params, latest)
            params = jax.tree_util.tree_map(jnp.asarray, params)
            start = latest
            print(f"restored step {latest}")

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        k_s = jax.random.fold_in(k_ev, step)
        tx = sample_event_masks(k_s, args.lambda_tx, 1.0, n)
        q_eff = q * tx[:, None].astype(q.dtype)
        if args.psi > 0:
            q_eff = mixing.psi_cap_mask(jax.random.fold_in(k_s, 7), q_eff, args.psi)
        batch = select_batch(data, step, args.batch_per_client)
        params, loss = jit_step(params, batch, q_eff)
        losses.append(float(loss))
        if args.unify_every and (step + 1) % args.unify_every == 0:
            hub = jnp.asarray((step // args.unify_every) % n, jnp.int32)
            params = unify_fn(params, hub)
        if (step + 1) % args.log_every == 0:
            dt = time.time() - t0
            print(f"step {step+1:5d} loss {np.mean(losses[-args.log_every:]):.4f} "
                  f"({dt/args.log_every:.2f}s/step)")
            t0 = time.time()
        if args.ckpt_dir and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt_dir, step + 1, jax.device_get(params))
            print(f"saved checkpoint @ {step+1}")

    print(f"final loss {np.mean(losses[-10:]):.4f} (first 10: {np.mean(losses[:10]):.4f})")
    return losses


if __name__ == "__main__":
    main()
