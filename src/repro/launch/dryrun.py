import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) pair.

For train shapes this lowers the DRACO window step (local grads + gossip
mix + apply); decode shapes lower ``serve_step`` (one token vs a KV/SSM
cache); prefill shapes lower the full-prompt forward. Prints
``memory_analysis()`` / ``cost_analysis()`` and appends roofline rows to
``results/dryrun.jsonl``.

Cost-term correction: XLA counts while-loop bodies ONCE (verified on this
backend), so the scan-over-layers step under-reports flops/bytes by ~the
depth. We therefore compile two additional *cost variants* at depth 1 and
depth 2 with the layer loop unrolled and inner attention loops disabled;
``body = cost(d2) - cost(d1)`` isolates one layer-group and
``total = cost(d1) + (G-1) * body`` reconstructs the full-depth terms.
The full-depth artifact compile still proves lowering/fit and provides
memory_analysis + the collective schedule.

Usage:
  python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k
  python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.launch.roofline import Roofline, collective_bytes, model_flops_analytic
from repro.models.model import block_pattern


def _build_lowered(cfg, shape, mesh, *, mix_mode="dense", psi=0,
                   unroll=False, cost_variant=False, mix_dtype=None,
                   blocked_threshold=8192, cache_shard="kv_heads",
                   vocab_chunk=0, seq_parallel=False):
    n_clients = mesh_lib.num_clients(mesh)
    if shape.mode == "train":
        md = jnp.bfloat16 if mix_dtype == "bf16" else None
        step = steps_lib.make_train_step(cfg, mesh, mix_mode=mix_mode, psi=psi,
                                         unroll=unroll, cost_variant=cost_variant,
                                         mix_dtype=md,
                                         blocked_threshold=blocked_threshold,
                                         vocab_chunk=vocab_chunk,
                                         seq_parallel=seq_parallel)
        param_sh, batch_sh, q_sh = steps_lib.make_shardings(mesh, cfg, shape)
        params_abs = steps_lib.stack_clients_abstract(
            steps_lib.param_specs_abstract(cfg), n_clients
        )
        batch_abs = steps_lib.train_batch_specs(cfg, shape, n_clients)
        q_abs = jax.ShapeDtypeStruct((n_clients, n_clients), jnp.float32)
        with mesh:
            jitted = jax.jit(step, in_shardings=(param_sh, batch_sh, q_sh),
                             out_shardings=(param_sh, None), donate_argnums=(0,))
            return jitted.lower(params_abs, batch_abs, q_abs)
    if shape.mode == "prefill":
        step = steps_lib.make_prefill_step(cfg, shape, mesh, unroll=unroll,
                                           cost_variant=cost_variant)
        scfg = steps_lib.serve_config(cfg, shape)
        param_sh, *_ = steps_lib.serve_shardings(mesh, cfg, shape)
        params_abs = steps_lib.param_specs_abstract(scfg)
        batch_abs = steps_lib.prefill_batch_specs(cfg, shape)
        caxes = mesh_lib.client_axes(mesh)
        cax = caxes if len(caxes) > 1 else caxes[0]
        from jax.sharding import NamedSharding, PartitionSpec as P

        bsh = {k: NamedSharding(mesh, P(cax, *([None] * (len(v.shape) - 1))))
               for k, v in batch_abs.items()}
        with mesh:
            jitted = jax.jit(step, in_shardings=(param_sh, bsh))
            return jitted.lower(params_abs, batch_abs)
    # decode
    step = steps_lib.make_serve_step(cfg, shape, mesh, unroll=unroll)
    scfg = steps_lib.serve_config(cfg, shape)
    param_sh, tok_sh, state_sh, cross_sh, _ = steps_lib.serve_shardings(
        mesh, cfg, shape, cache_shard=cache_shard)
    params_abs = steps_lib.param_specs_abstract(scfg)
    tok_abs, state_abs, cross_abs = steps_lib.serve_input_specs(cfg, shape)
    with mesh:
        if cross_abs is not None:
            jitted = jax.jit(step, in_shardings=(param_sh, tok_sh, state_sh, cross_sh),
                             out_shardings=(None, state_sh), donate_argnums=(2,))
            return jitted.lower(params_abs, tok_abs, state_abs, cross_abs)
        jitted = jax.jit(step, in_shardings=(param_sh, tok_sh, state_sh),
                         out_shardings=(None, state_sh), donate_argnums=(2,))
        return jitted.lower(params_abs, tok_abs, state_abs)


def _compile_and_cost(lowered):
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cost = cost or {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    counts = coll.pop("_counts")
    return compiled, {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
        "coll_breakdown": coll,
        "coll_counts": counts,
    }


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               mix_mode: str = "dense", psi: int = 0, verbose: bool = True,
               cost_correct: bool = True, mix_dtype=None,
               blocked_threshold: int = 8192, cache_shard: str = "kv_heads",
               vocab_chunk: int = 0, seq_parallel: bool = False):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    _, n_groups = block_pattern(cfg)

    # ---- artifact compile (full depth; proves lowering + fit) ------------
    t0 = time.time()
    lowered = _build_lowered(cfg, shape, mesh, mix_mode=mix_mode, psi=psi,
                             mix_dtype=mix_dtype,
                             blocked_threshold=blocked_threshold,
                             cache_shard=cache_shard, vocab_chunk=vocab_chunk,
                             seq_parallel=seq_parallel)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled, art = _compile_and_cost(lowered)
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                mem[k] = getattr(ma, k, None)
    except Exception as e:  # pragma: no cover
        mem["error"] = str(e)

    # ---- cost-correction compiles (depth 1 & 2, unrolled) -----------------
    corrected = dict(art)
    corr_meta = {"method": "artifact-only"}
    if cost_correct and n_groups >= 2:
        try:
            c1cfg = steps_lib.depth_config(cfg, 1)
            c2cfg = steps_lib.depth_config(cfg, 2)
            _, c1 = _compile_and_cost(_build_lowered(
                c1cfg, shape, mesh, mix_mode=mix_mode, psi=psi,
                unroll=True, cost_variant=True, mix_dtype=mix_dtype,
                cache_shard=cache_shard))
            _, c2 = _compile_and_cost(_build_lowered(
                c2cfg, shape, mesh, mix_mode=mix_mode, psi=psi,
                unroll=True, cost_variant=True, mix_dtype=mix_dtype,
                cache_shard=cache_shard))
            body = {k: c2[k] - c1[k] for k in ("flops", "bytes", "coll")}
            corrected = {
                k: c1[k] + (n_groups - 1) * body[k]
                for k in ("flops", "bytes", "coll")
            }
            corr_meta = {
                "method": "depth-extrapolation",
                "depth1": {k: c1[k] for k in ("flops", "bytes", "coll")},
                "depth2": {k: c2[k] for k in ("flops", "bytes", "coll")},
                "artifact": {k: art[k] for k in ("flops", "bytes", "coll")},
            }
        except Exception as e:  # pragma: no cover
            corr_meta = {"method": "artifact-only", "corr_error": repr(e)}

    n_dev = 512 if multi_pod else 256
    roof = Roofline(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        mode=shape.mode,
        flops_per_device=corrected["flops"],
        bytes_per_device=corrected["bytes"],
        coll_bytes_per_device=corrected["coll"],
        coll_breakdown={**art["coll_breakdown"], "counts": art["coll_counts"]},
        model_flops=model_flops_analytic(cfg, shape),
        peak_memory_bytes=float(mem.get("temp_size_in_bytes") or 0.0),
        n_devices=n_dev,
    )
    row = roof.row()
    row.update({
        "mix_mode": mix_mode,
        "psi": psi,
        "mix_dtype": mix_dtype or "f32",
        "blocked_threshold": blocked_threshold,
        "cache_shard": cache_shard,
        "vocab_chunk": vocab_chunk,
        "seq_parallel": seq_parallel,
        "t_lower_s": t_lower,
        "t_compile_s": t_compile,
        "memory_analysis": mem,
        "cost_correction": corr_meta,
    })
    if verbose:
        print(f"== {arch} x {shape_name} x {mesh_name} (mode={shape.mode}, mix={mix_mode}) ==")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s  [{corr_meta['method']}]")
        print(f"  memory_analysis: {mem}")
        print(f"  cost (corrected): flops/dev={row['flops_per_device']:.3e} "
              f"bytes/dev={row['bytes_per_device']:.3e} coll/dev={row['coll_bytes_per_device']:.3e}")
        print(f"  collective schedule (artifact): {art['coll_counts']}")
        print(f"  roofline: compute={roof.t_compute*1e3:.2f}ms memory={roof.t_memory*1e3:.2f}ms "
              f"collective={roof.t_collective*1e3:.2f}ms -> {roof.bottleneck}-bound")
        print(f"  MODEL_FLOPS={roof.model_flops:.3e} useful_ratio={roof.useful_flops_ratio:.3f}")
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mix", default="dense", choices=["dense", "ring", "none"])
    ap.add_argument("--psi", type=int, default=0)
    ap.add_argument("--no-correct", action="store_true")
    ap.add_argument("--mix-dtype", default=None, choices=[None, "bf16"])
    ap.add_argument("--train-attn-blocked", action="store_true",
                    help="use blocked online-softmax attention in train_4k")
    ap.add_argument("--cache-shard", default="kv_heads",
                    choices=["kv_heads", "head_dim", "seq"])
    ap.add_argument("--ce-chunk", type=int, default=0)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    args = ap.parse_args()

    if args.all:
        import gc
        import traceback

        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        failures = []
        # cheap modes first so partial progress covers more pairs
        shape_order = sorted(SHAPES, key=lambda s: {"decode": 0, "prefill": 1,
                                                    "train": 2}[SHAPES[s].mode])
        for shape in shape_order:
            for arch in ARCH_IDS:
                for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
                    try:
                        row = lower_pair(arch, shape, multi_pod=mp,
                                         mix_mode=args.mix, psi=args.psi,
                                         cost_correct=not args.no_correct)
                        with open(args.out, "a") as f:
                            f.write(json.dumps(row) + "\n")
                    except Exception:
                        traceback.print_exc()
                        failures.append((arch, shape, mp))
                    jax.clear_caches()
                    gc.collect()
        if failures:
            print("FAILURES:", failures)
            sys.exit(1)
        print("ALL PAIRS LOWERED+COMPILED OK")
        return

    assert args.arch and args.shape
    row = lower_pair(args.arch, args.shape, multi_pod=args.multi_pod,
                     mix_mode=args.mix, psi=args.psi,
                     cost_correct=not args.no_correct,
                     mix_dtype=args.mix_dtype,
                     blocked_threshold=1024 if args.train_attn_blocked else 8192,
                     cache_shard=args.cache_shard, vocab_chunk=args.ce_chunk,
                     seq_parallel=args.seq_parallel)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(row) + "\n")


if __name__ == "__main__":
    main()
