"""Production-plane step functions + abstract input specs.

``make_train_step``: one DRACO superposition window on the mesh — every
client (= model-shard group on the ("pod","data") axes) runs a local
grad step, forms Delta, and the row-stochastic gossip mix is applied as a
collective over the client axis. Event masks / channel masks arrive as
the per-window effective Q (q_eff) input, so the compiled step is purely
data-dependent (no host control flow).

``make_serve_step`` / ``make_prefill_step``: decode one token against a
KV/SSM cache; prefill a full prompt. Serving uses the *unified* model
(single param copy), per DESIGN.md §4.

``input_specs``: ShapeDtypeStruct stand-ins for every model input of an
(arch x shape) pair — weak-type-correct, shardable, no allocation.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import mixing
from repro.launch import mesh as mesh_lib
from repro.models import model as M
from repro.sharding.axes import default_rules, train_rules, use_rules
from repro.sharding.specs import tree_param_specs


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig, n_clients: int):
    """Per-client-stacked batch: leaves lead with (N, b, ...)."""
    assert shape.global_batch % n_clients == 0, (shape.global_batch, n_clients)
    b = shape.global_batch // n_clients
    S = shape.seq_len
    specs: Dict[str, Any] = {}
    if cfg.embeds_in:
        specs["embeds"] = jax.ShapeDtypeStruct((n_clients, b, S, cfg.d_model), jnp.bfloat16)
        specs["labels"] = jax.ShapeDtypeStruct((n_clients, b, S), jnp.int32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((n_clients, b, S), jnp.int32)
    if cfg.family == "vlm":
        specs["cross_embeds"] = jax.ShapeDtypeStruct(
            (n_clients, b, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16
        )
    return specs


def serve_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Decode-step inputs: current token + cache state (+ cross KV)."""
    B, S = shape.global_batch, shape.seq_len
    serve_cfg = serve_config(cfg, shape)
    state = jax.eval_shape(lambda: M.init_decode_state(serve_cfg, B, S))
    if cfg.embeds_in:
        tok = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
    else:
        tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    cross = None
    if cfg.family == "vlm":
        pe = jax.ShapeDtypeStruct((B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
        params_s = jax.eval_shape(lambda k: M.init_params(k, serve_cfg), jax.random.PRNGKey(0))
        cross = jax.eval_shape(
            lambda p, e: M.init_cross_kv(p, serve_cfg, e), params_s, pe
        )
    return tok, state, cross


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if cfg.embeds_in:
        specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.family == "vlm":
        specs["cross_embeds"] = jax.ShapeDtypeStruct((B, cfg.num_patch_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def param_specs_abstract(cfg: ModelConfig):
    return jax.eval_shape(lambda k: M.init_params(k, cfg), jax.random.PRNGKey(0))


def stack_clients_abstract(params_abs, n_clients: int):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct((n_clients,) + tuple(l.shape), l.dtype), params_abs
    )


def serve_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Serving variant: attention archs get a sliding window at 500k ctx
    (sub-quadratic requirement); ssm/hybrid decode natively."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm", "audio"):
        return cfg.with_(sliding_window=8192)
    if shape.name == "long_500k" and cfg.family == "hybrid":
        # hybrid: SSM layers are O(1); the shared attn block uses a window
        return cfg.with_(sliding_window=8192)
    return cfg


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------


def make_shardings(mesh, cfg: ModelConfig, shape: ShapeConfig):
    """(param_shardings (client-stacked), batch_shardings, q_sharding)."""
    caxes = mesh_lib.client_axes(mesh)
    cax = caxes if len(caxes) > 1 else caxes[0]
    n_clients = mesh_lib.num_clients(mesh)
    params_abs = stack_clients_abstract(param_specs_abstract(cfg), n_clients)
    pspecs = tree_param_specs(params_abs, prefix=(cax,), mesh=mesh)
    param_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)

    def batch_sh(leaf_spec):
        return NamedSharding(mesh, leaf_spec)

    bspecs = {}
    for name, sds in train_batch_specs(cfg, shape, n_clients).items():
        spec = P(cax, *([None] * (len(sds.shape) - 1)))
        bspecs[name] = batch_sh(spec)
    q_sh = NamedSharding(mesh, P(None, None))
    return param_sh, bspecs, q_sh


def serve_shardings(mesh, cfg: ModelConfig, shape: ShapeConfig,
                    cache_shard: str = "kv_heads"):
    """Shardings for (params single-copy, token, decode state, cross_kv).

    cache_shard: 'kv_heads' shards the KV-head axis over "model"
    (baseline; falls back to replicated when kv_heads % 16 != 0 — the
    GQA pathology measured in §Roofline). 'head_dim' shards the head_dim
    axis instead (always divisible; attention contracts over it with a
    psum — Megatron-style reduction split). 'seq' shards the cache
    length axis over "model"."""
    caxes = mesh_lib.client_axes(mesh)
    cax = caxes if len(caxes) > 1 else caxes[0]
    B = shape.global_batch
    batch_shardable = B % mesh_lib.num_clients(mesh) == 0
    batch_ax = cax if batch_shardable else None
    # long-context batch=1: shard the cache sequence axis over 'data'
    seq_ax = None if batch_shardable else "data"

    scfg = serve_config(cfg, shape)
    params_abs = param_specs_abstract(scfg)
    pspecs = tree_param_specs(params_abs, prefix=(), mesh=mesh)
    param_sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs)

    tok, state, cross = serve_input_specs(cfg, shape)
    if cfg.embeds_in:
        tok_sh = NamedSharding(mesh, P(batch_ax, None, None))
    else:
        tok_sh = NamedSharding(mesh, P(batch_ax))

    from repro.sharding.specs import filter_divisible

    def cache_spec(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
        nd = len(leaf.shape)
        if nd == 0:
            spec = P()
        elif "ssm" in name and nd == 4:  # conv state (n_groups, B, W-1, ch)
            spec = P(None, batch_ax, None, "model")
        elif "ssm" in name and nd == 5:  # h (n_groups, B, H, N, P)
            spec = P(None, batch_ax, "model", None, None)
        elif nd == 5:  # KV cache (n_groups, B, C, Hkv, hd)
            if cache_shard == "head_dim":
                spec = P(None, batch_ax, seq_ax, None, "model")
            elif cache_shard == "seq":
                spec = P(None, batch_ax, "model", None, None)
            else:
                spec = P(None, batch_ax, seq_ax, "model", None)
        else:
            spec = P(*([None] * nd))
        return filter_divisible(spec, leaf.shape, mesh)

    state_sh = jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, cache_spec(p, l)), state,
    )
    cross_sh = None
    if cross is not None:
        cross_sh = jax.tree_util.tree_map(
            lambda l: NamedSharding(
                mesh, filter_divisible(P(None, batch_ax, None, "model", None), l.shape, mesh)
            ),
            cross,
        )
    return param_sh, tok_sh, state_sh, cross_sh, scfg


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def depth_config(cfg: ModelConfig, k: int) -> ModelConfig:
    """Same width, depth reduced to k layer-groups (cost-correction compiles)."""
    from repro.models.model import block_pattern

    _, n_groups = block_pattern(cfg)
    unit = cfg.num_layers // n_groups
    return cfg.with_(num_layers=unit * k)


def make_train_step(cfg: ModelConfig, mesh, *, lr: float = 1e-3,
                    mix_mode: str = "dense", psi: int = 0,
                    unroll: bool = False, cost_variant: bool = False,
                    mix_dtype=None, blocked_threshold: int = 8192,
                    vocab_chunk: int = 0, seq_parallel: bool = False):
    """One DRACO window: local grad -> Delta -> gossip mix -> apply.

    mix_mode: 'dense' (paper-faithful row-stochastic einsum over the
    client axis), 'ring' (collective_permute cycle lowering), or 'none'
    (no gossip — isolates local compute for roofline attribution).
    mix_dtype: gossip accumulation dtype (f32 faithful; bf16 halves
    collective bytes). blocked_threshold: seq length at which training
    attention switches to the blocked online-softmax path (memory knob).
    cost_variant disables inner-loop attention so XLA cost_analysis sees
    every flop (see dryrun depth-correction).
    """
    caxes = mesh_lib.client_axes(mesh)
    rules = train_rules(mesh, seq_parallel=seq_parallel)
    bat = 10**9 if cost_variant else blocked_threshold
    spmd_axis = caxes if len(caxes) > 1 else caxes[0]

    def train_step(params, batch, q_eff):
        def client_loss(p_i, b_i):
            return M.lm_loss(p_i, cfg, b_i, blocked_attn_threshold=bat,
                             unroll_groups=unroll, vocab_chunk=vocab_chunk)

        with use_rules(rules):
            loss, grads = jax.vmap(
                jax.value_and_grad(client_loss), spmd_axis_name=spmd_axis
            )(params, batch)
            delta = jax.tree_util.tree_map(lambda g: (-lr * g).astype(g.dtype), grads)
            if mix_mode == "dense":
                md = mix_dtype or jnp.float32
                add = mixing.mix_dense(q_eff, delta, compute_dtype=md)
                new_params = jax.tree_util.tree_map(
                    lambda p, a: p + a.astype(p.dtype), params, add
                )
            elif mix_mode == "ring":
                mixed = mixing.mix_ring_shardmap(mesh, caxes, delta)
                new_params = jax.tree_util.tree_map(
                    lambda p, m: p + m.astype(p.dtype), params, mixed
                )
            elif mix_mode == "none":
                new_params = jax.tree_util.tree_map(
                    lambda p, d: p + d.astype(p.dtype), params, delta
                )
            else:
                raise ValueError(mix_mode)
        return new_params, loss.mean()

    return train_step


def make_unify_step(cfg: ModelConfig, mesh):
    """Periodic unification: hub's params broadcast to every client."""

    def unify_step(params, hub):
        return jax.tree_util.tree_map(
            lambda p: jnp.broadcast_to(
                jax.lax.dynamic_index_in_dim(p, hub, 0, keepdims=True), p.shape
            ),
            params,
        )

    return unify_step


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                      unroll: bool = False, cost_variant: bool = False):
    scfg = serve_config(cfg, shape)
    rules = default_rules(mesh)
    bat = 10**9 if cost_variant else 8192

    def prefill_step(params, batch):
        with use_rules(rules):
            logits, _ = M.apply_model(params, scfg, batch,
                                      blocked_attn_threshold=bat,
                                      unroll_groups=unroll)
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                    unroll: bool = False):
    scfg = serve_config(cfg, shape)
    rules = default_rules(mesh)

    def serve_step(params, tok, state, cross_kv=None):
        with use_rules(rules):
            logits, state = M.decode_step(params, scfg, tok, state, cross_kv,
                                          unroll_groups=unroll)
        return logits, state

    return serve_step
