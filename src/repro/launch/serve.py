"""Batched serving loop: prefill + decode of the DRACO-unified model.

A minimal production-shaped server: requests arrive as (prompt tokens,
max_new); the loop batches them, runs prefill to build KV/SSM caches
via repeated decode over prompt tokens (simple, cache-exact), then decodes
greedily with one compiled ``serve_step``.

Example (reduced config on CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.models import model as M


def serve_batch(cfg, params, prompts, max_new: int, *, cross_embeds=None,
                greedy: bool = True, key=None):
    """prompts: (B, P) int32. Returns (B, max_new) generated tokens."""
    B, P = prompts.shape
    state = M.init_decode_state(cfg, B, P + max_new)
    cross_kv = None
    if cfg.family == "vlm":
        assert cross_embeds is not None
        cross_kv = M.init_cross_kv(params, cfg, cross_embeds)

    decode = jax.jit(lambda p, t, s: M.decode_step(p, cfg, t, s, cross_kv))

    def tok_input(tok):
        # embeds-in archs (audio): feed the codebook-token embedding back
        if cfg.embeds_in:
            return params["embed"][tok][:, None, :].astype(jnp.dtype(cfg.dtype))
        return tok

    # prefill by stepping through the prompt (cache-exact, compile-once)
    logits = None
    for i in range(P):
        logits, state = decode(params, tok_input(prompts[:, i]), state)

    out = []
    tok = jnp.argmax(logits, axis=-1)
    for i in range(max_new):
        out.append(tok)
        logits, state = decode(params, tok_input(tok), state)
        if greedy:
            tok = jnp.argmax(logits, axis=-1)
        else:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, logits)
    return jnp.stack(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    cross = None
    if cfg.family == "vlm":
        cross = jax.random.normal(
            key, (args.batch, cfg.num_patch_tokens, cfg.d_model)
        ).astype(jnp.dtype(cfg.dtype))

    t0 = time.time()
    toks = serve_batch(cfg, params, prompts, args.new_tokens, cross_embeds=cross)
    toks.block_until_ready()
    dt = time.time() - t0
    total_new = args.batch * args.new_tokens
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s aggregate)")
    print("sample:", np.asarray(toks[0])[:16])
    assert np.isfinite(np.asarray(toks)).all()
    return toks


if __name__ == "__main__":
    main()
