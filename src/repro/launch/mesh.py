"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model") — 16 DRACO
clients x 16-way tensor parallel. Multi-pod: (2, 16, 16) = 512 chips,
axes ("pod", "data", "model") — 32 clients spanning 2 pods; the gossip
graph's client axis is the flattened ("pod", "data") product, so gossip
edges cross the inter-pod links (DCN/optical) exactly where the paper's
protocol tolerates delay.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for CPU integration tests (requires >= prod(shape) devices)."""
    return jax.make_mesh(shape, axes)


def make_sweep_mesh(num_data: int | None = None):
    """Mesh for `repro.api.sweep.simulate_sweep(..., mesh=...)`: every
    device on the "data" axis (the `sharding/axes.py` "clients" rule maps
    the DRACO client axis onto it), trivial "model" axis — protocol
    sweeps are client-parallel, not tensor-parallel. `num_data` defaults
    to all visible devices; the client count N must be divisible by it
    for the axis to actually shard (`specs.filter_divisible` falls back
    to replicated otherwise)."""
    n = num_data if num_data is not None else len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def client_axes(mesh) -> tuple:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_clients(mesh) -> int:
    n = 1
    for a in client_axes(mesh):
        n *= mesh.shape[a]
    return n
