"""Roofline-term extraction from compiled dry-run artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
    collective = collective_bytes_per_device / link_bw    (~50 GB/s/link)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device — XLA
reports the partitioned module). Collective bytes are parsed from the
compiled HLO text: the *result* size of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute is the per-device
receive volume.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e-ish)
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+(\S+)\(", line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        op_base = op.split(".")[0]
        # fusion wrappers like all-gather-start
        for kind in _COLLECTIVES:
            if op_base == kind or op_base == kind + "-start":
                out[kind] += _shape_bytes(shape_str)
                counts[kind] += 1
                break
    out["_counts"] = counts  # type: ignore
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    mode: str  # train | prefill | decode
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0  # 6*N*D (analytic, global)
    peak_memory_bytes: float = 0.0
    n_devices: int = 256

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "mode": self.mode,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "peak_memory_bytes": self.peak_memory_bytes,
            "n_devices": self.n_devices,
        }


def model_flops_analytic(cfg, shape) -> float:
    """6*N_active*D for train (fwd+bwd), 2*N_active*D for inference."""
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
