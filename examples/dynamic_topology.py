"""Dynamic scenarios: DRACO on time-varying networks, via `repro.api`.

Runs the same DRACO protocol under all four registered scenario
generators — the frozen graph, Markov edge churn, random-waypoint
mobility (graph re-derived from channel geometry each epoch), and a
heavy-tailed straggler profile — and prints a side-by-side table of
accuracy and consensus distance. Each run is ONE compiled `simulate`
scan; the scenario's schedule rings are indexed in-jit at every window,
so a time-varying topology costs the same dispatch as a frozen one.

  PYTHONPATH=src python examples/dynamic_topology.py
"""
import jax

from repro.api import simulate
from repro.configs.draco_paper import EMNIST
from repro.core.channel import ChannelConfig
from repro.core.protocol import DracoConfig
from repro.data.synthetic import federated_classification, make_mlp
from repro.scenarios import list_scenarios

SCENARIOS = {
    "static": {},
    "markov-edge-flip": {"steps": 32, "churn": 0.2},
    "random-waypoint": {"steps": 32, "speed": 40.0},
    "straggler-profile": {"steps": 32, "straggler_frac": 0.4,
                          "slowdown": 10.0, "duty": 0.5},
}

# psi must track in-degree (fig3 makes the same move on complete
# graphs): the cycle-based scenarios have 2 in-neighbors, but
# random-waypoint's geometric graph links ~half the disk — a tiny fixed
# cap starves it (accuracy collapses to near-local-only), so the cap is
# lifted entirely there (psi=0 = unbounded; sweep psi to see the cliff).
PSI = {"random-waypoint": 0}


def main():
    t = EMNIST
    n, windows = 16, 300
    key = jax.random.PRNGKey(0)
    k_data, k_model, k_sim, k_sched = jax.random.split(key, 4)

    print(f"== DRACO under dynamic scenarios: {n} clients, {windows} windows ==")
    print(f"registered scenarios: {', '.join(list_scenarios())}")
    train, test = federated_classification(
        k_data, n, input_dim=t.input_dim, num_classes=t.num_classes,
        per_client=t.samples_per_client)
    params0, apply, loss, acc = make_mlp(k_model, t.input_dim, t.hidden,
                                         t.num_classes)
    cfg = DracoConfig(
        num_clients=n, lr=t.lr, local_batches=t.local_batches,
        batch_size=t.batch_size, lambda_grad=0.3, lambda_tx=0.3,
        unify_period=50, psi=6, topology="cycle", max_delay_windows=4,
        channel=ChannelConfig(message_bytes=t.message_bytes, gamma_max=10.0))

    print(f"{'scenario':<20} {'final acc':>9} {'consensus':>9} {'msgs':>7}")
    for name, knobs in SCENARIOS.items():
        cfg_s = cfg.replace(psi=PSI.get(name, cfg.psi))
        st, trace = simulate("draco", cfg_s, params0, loss, train,
                             num_steps=windows, key=k_sim, eval_every=100,
                             eval_fn=acc, eval_data=test, scenario=name,
                             scenario_key=k_sched, scenario_kwargs=knobs)
        a = float(trace.metrics["accuracy"][-1])
        c = float(trace.metrics["consensus"][-1])
        print(f"{name:<20} {a:>9.3f} {c:>9.4f} {int(st.total_accept.sum()):>7}")
    print("done — one simulator, four workloads: churn, mobility and "
          "stragglers ride the same compiled scan as the frozen graph.")


if __name__ == "__main__":
    main()
