"""Batched serving of a DRACO-unified model.

Simulates a request queue (prompts of mixed length, padded into a batch),
runs prefill + greedy decode with the KV-cache serve path, and reports
per-request latency/throughput. Works for dense, SSM (O(1)-state), MoE,
VLM and audio archs.

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-2.7b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_reduced
from repro.launch.serve import serve_batch
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)

    # request queue: mixed prompt lengths, left-padded into one batch
    rng = np.random.default_rng(0)
    lens = rng.integers(4, args.max_prompt, size=args.requests)
    B, P = args.requests, int(lens.max())
    prompts = np.zeros((B, P), np.int32)
    for i, L in enumerate(lens):
        prompts[i, P - L:] = rng.integers(0, cfg.vocab_size, size=L)
    prompts = jnp.asarray(prompts)
    print(f"== serving {B} requests (prompt lens {list(lens)}) with {cfg.name} ==")

    cross = None
    if cfg.family == "vlm":
        cross = jax.random.normal(key, (B, cfg.num_patch_tokens, cfg.d_model))

    t0 = time.time()
    toks = serve_batch(cfg, params, prompts, args.new_tokens, cross_embeds=cross)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    for i in range(B):
        print(f"req {i}: prompt_len={lens[i]:3d} -> {np.asarray(toks[i])[:8]}...")
    print(f"aggregate: {B * args.new_tokens / dt:.1f} tok/s "
          f"({dt / args.new_tokens * 1e3:.0f} ms/decode-step for batch {B})")
    assert np.isfinite(np.asarray(toks)).all()


if __name__ == "__main__":
    main()
