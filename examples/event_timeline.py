"""The continuous-time event engine: exact timelines, via `repro.events`.

The windowed engine discretizes DRACO's merged Poisson process into
superposition windows; `simulate_events` keeps the exact timeline — the
run is pre-sampled into a sorted event tape and scanned in one jitted
call, one `lax.switch` dispatch per event. This example runs the whole
event family on the same tape and compares it against the windowed
engine at the same rates, horizon, and task:

  draco-event       exact-timeline DRACO (the numpy event_list
                    reference, compiled);
  fedasync-gossip   + FedAsync staleness damping at the exact
                    continuous message age;
  event-triggered   + threshold broadcast suppression (watch tx_sent
                    drop while accuracy holds);
  draco (windowed)  the superposition-window discretization.

  PYTHONPATH=src python examples/event_timeline.py
"""
import jax
import numpy as np

from repro.api import simulate, simulate_events
from repro.events import EventConfig, events_context
from repro.tasks import get_task

N, HORIZON = 16, 40.0


def main():
    cfg = EventConfig(
        num_clients=N, lr=0.1, local_batches=1, batch_size=32,
        lambda_grad=0.6, lambda_tx=0.6, unify_period=20, psi=4,
        topology="cycle", max_delay_windows=4,
        staleness="poly", staleness_a=0.5,     # fedasync-gossip knobs
        trigger_threshold=0.15,                # event-triggered knob
    )
    task = get_task("linear-softmax")
    key = jax.random.PRNGKey(0)
    data, eval_data = task.make_data(jax.random.PRNGKey(1), N)

    # one tape, shared by every event algorithm: same timeline, so the
    # comparison isolates the algorithmic difference
    ctx = events_context(cfg, task=task, data=data,
                         params0=task.init_params(key), horizon=HORIZON)
    print(f"tape: {ctx.tape.num_valid} events "
          f"(capacity {ctx.tape.capacity}) over {HORIZON:.0f}s "
          f"-> {ctx.tape.counts()}")

    print(f"\n{'algorithm':>18} {'accuracy':>9} {'broadcasts':>11}")
    for algo in ("draco-event", "fedasync-gossip", "event-triggered"):
        st, trace = simulate_events(algo, cfg, ctx=ctx, key=key,
                                    eval_every=ctx.tape.capacity,
                                    eval_data=eval_data)
        acc = float(trace.metrics[task.metric_name][-1])
        print(f"{algo:>18} {acc:9.3f} {int(np.asarray(st.tx_sent).sum()):11d}")

    # the windowed view of the same process: one step per window
    st, trace = simulate("draco", cfg, task=task, data=data,
                         num_steps=int(HORIZON / cfg.window), key=key,
                         eval_every=int(HORIZON / cfg.window),
                         eval_data=eval_data)
    acc = float(trace.metrics[task.metric_name][-1])
    print(f"{'draco (windowed)':>18} {acc:9.3f} {'':>11}")


if __name__ == "__main__":
    main()
