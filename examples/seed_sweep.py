"""Seed x Psi sweep in ONE compiled device call (`repro.api.simulate_sweep`).

The paper's claims are statements about sweeps — so the API makes the
sweep the unit of work: this example runs a 4-seed x 3-Psi DRACO grid
(12 runs) as a single XLA program. Seeds ride a vmapped axis (row k is
bit-for-bit the solo `simulate()` with that seed), Psi rides a scanned
*traced-override* axis (one compile for the whole grid, no per-config
retrace), and accuracy/consensus sample in-jit.

  PYTHONPATH=src python examples/seed_sweep.py
"""
import jax
import numpy as np

from repro.api import make_context, simulate_sweep
from repro.core.channel import ChannelConfig
from repro.core.protocol import DracoConfig
from repro.data.synthetic import federated_classification, make_mlp

SEEDS, PSIS, WINDOWS, EVERY = 4, (1, 4, 24), 120, 40


def total_accept(state):
    """final_fn: keep only the message counters out of the grid states."""
    return state.total_accept


def main():
    n = 12
    key = jax.random.PRNGKey(0)
    k_data, k_model, k_sim = jax.random.split(key, 3)
    train, test = federated_classification(k_data, n, input_dim=16,
                                           num_classes=5, per_client=128)
    params0, apply, loss, acc = make_mlp(k_model, 16, (32,), 5)
    cfg = DracoConfig(
        num_clients=n, lr=0.05, local_batches=1, batch_size=16,
        lambda_grad=0.3, lambda_tx=0.3, unify_period=50, psi=PSIS[0],
        topology="cycle", max_delay_windows=4,
        channel=ChannelConfig(message_bytes=13_000, gamma_max=10.0))
    grid = [cfg.replace(psi=p) for p in PSIS]
    ctx = make_context(grid[0], loss, train, params0=params0)

    print(f"== simulate_sweep: {SEEDS} seeds x {len(PSIS)} Psi values, "
          f"{WINDOWS} windows, one device call ==")
    msgs, trace = simulate_sweep(
        "draco", grid, params0, loss, train, num_steps=WINDOWS,
        keys=jax.random.split(k_sim, SEEDS), eval_every=EVERY, eval_fn=acc,
        eval_data=test, ctx=ctx, final_fn=total_accept)

    accs = trace.metrics["accuracy"]  # (G, K, E)
    print("psi,final_acc_mean,final_acc_std,consensus_mean,msgs_mean")
    for g, psi in enumerate(PSIS):
        final = accs[g, :, -1]
        cons = trace.metrics["consensus"][g, :, -1]
        print(f"{psi},{final.mean():.3f},{final.std():.3f},"
              f"{cons.mean():.4f},{np.asarray(msgs[g]).sum(-1).mean():.0f}")
    print("done — seed means with error bars from one compiled call; "
          "swap `schedules=` in for churn/straggler grids.")


if __name__ == "__main__":
    main()
