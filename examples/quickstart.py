"""Quickstart: DRACO at the paper's experiment scale.

25 clients, EMNIST-like federated classification, cycle topology,
unreliable wireless channel, Psi message cap — the whole Algorithm 1
pipeline in ~a minute on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.draco_paper import EMNIST
from repro.core.channel import ChannelConfig
from repro.core.protocol import (
    DracoConfig,
    build_graph,
    init_state,
    run_windows,
    virtual_global_model,
)
from repro.data.synthetic import federated_classification, make_mlp


def main():
    t = EMNIST
    n = 25
    key = jax.random.PRNGKey(0)
    k_data, k_model, k_sim = jax.random.split(key, 3)

    print(f"== DRACO quickstart: {n} clients, {t.name}-like task, cycle topology ==")
    train, test = federated_classification(
        k_data, n, input_dim=t.input_dim, num_classes=t.num_classes,
        per_client=t.samples_per_client)
    params0, apply, loss, acc = make_mlp(k_model, t.input_dim, t.hidden, t.num_classes)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params0))
    print(f"model: MLP {t.hidden}, {n_params} params "
          f"(paper CNN: ~{t.message_bytes} B)")

    cfg = DracoConfig(
        num_clients=n, lr=t.lr, local_batches=t.local_batches,
        batch_size=t.batch_size, lambda_grad=0.3, lambda_tx=0.3,
        unify_period=50, psi=6, topology="cycle", max_delay_windows=4,
        channel=ChannelConfig(message_bytes=t.message_bytes, gamma_max=10.0))
    q, adj = build_graph(cfg)
    st = init_state(k_sim, cfg, params0)

    tx_, ty_ = test
    for seg in range(6):
        st = run_windows(st, cfg, q, adj, loss, train, 100)
        per = jax.vmap(lambda p: acc(p, tx_, ty_))(st.params)
        vg = virtual_global_model(st.params)
        print(f"window {int(st.window_idx):4d}: mean client acc {float(per.mean()):.3f} "
              f"(std {float(per.std()):.4f}), virtual-global acc "
              f"{float(acc(vg, tx_, ty_)):.3f}, msgs this period "
              f"{int(st.accept_count.sum())}")
    print("done — decoupled computation/communication, no global clock, "
          "row-stochastic gossip, Psi-capped reception.")


if __name__ == "__main__":
    main()
