"""Quickstart: DRACO at the paper's experiment scale, via `repro.api`.

25 clients, EMNIST-like federated classification, cycle topology,
unreliable wireless channel, Psi message cap — the whole Algorithm 1
pipeline in ~a minute on CPU, through the unified algorithm registry:
one `simulate(...)` call runs the full 600-window protocol inside a
single compiled scan, sampling accuracy + consensus distance in-jit.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.api import get_algorithm, list_algorithms, simulate
from repro.configs.draco_paper import EMNIST
from repro.core.channel import ChannelConfig
from repro.core.protocol import DracoConfig, virtual_global_model
from repro.data.synthetic import federated_classification, make_mlp


def main():
    t = EMNIST
    n = 25
    key = jax.random.PRNGKey(0)
    k_data, k_model, k_sim = jax.random.split(key, 3)

    print(f"== DRACO quickstart: {n} clients, {t.name}-like task, cycle topology ==")
    print(f"registered algorithms: {', '.join(list_algorithms())}")
    train, test = federated_classification(
        k_data, n, input_dim=t.input_dim, num_classes=t.num_classes,
        per_client=t.samples_per_client)
    params0, apply, loss, acc = make_mlp(k_model, t.input_dim, t.hidden, t.num_classes)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params0))
    print(f"model: MLP {t.hidden}, {n_params} params "
          f"(paper CNN: ~{t.message_bytes} B)")

    cfg = DracoConfig(
        num_clients=n, lr=t.lr, local_batches=t.local_batches,
        batch_size=t.batch_size, lambda_grad=0.3, lambda_tx=0.3,
        unify_period=50, psi=6, topology="cycle", max_delay_windows=4,
        channel=ChannelConfig(message_bytes=t.message_bytes, gamma_max=10.0))

    st, trace = simulate("draco", cfg, params0, loss, train, num_steps=600,
                         key=k_sim, eval_every=100, eval_fn=acc, eval_data=test)
    for step, a, c in zip(trace.step, trace.metrics["accuracy"],
                          trace.metrics["consensus"]):
        print(f"window {int(step):4d}: mean client acc {float(a):.3f}, "
              f"consensus distance {float(c):.4f}")

    algo = get_algorithm("draco")
    vg = virtual_global_model(algo.eval_params(st))
    print(f"virtual-global acc {float(acc(vg, test[0], test[1])):.3f}, "
          f"msgs accepted total {int(st.total_accept.sum())}")
    print("done — decoupled computation/communication, no global clock, "
          "row-stochastic gossip, Psi-capped reception.")


if __name__ == "__main__":
    main()
