"""Faithful continuous-time DRACO simulation (paper Algorithm 2).

Unlike the compiled superposition-window engine (repro.core.protocol),
this example runs the *exact* event-driven timeline: per-client Poisson
event lists are generated, merged and sorted (Alg. 2 lines 1-15), then
processed one event at a time with real-valued SINR transmission delays —
the reference semantics the windowed engine approximates. At the end it
runs the compiled engine on the same setup through `repro.api.simulate`
(one window per second of horizon) to show the two agree.

  PYTHONPATH=src python examples/wireless_sim.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.channel import ChannelConfig, place_nodes, transmission_delays
from repro.core.events import event_list
from repro.core.topology import adjacency
from repro.data.synthetic import federated_classification, make_mlp


def main():
    n, horizon = 10, 400.0
    lam_grad = lam_tx = 0.1
    unify_period = 100.0
    psi = 4
    chan = ChannelConfig(message_bytes=51_640, gamma_max=10.0)

    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    train, test = federated_classification(k1, n, input_dim=10, num_classes=5,
                                           per_client=300)
    xs, ys = train
    tx_t, ty_t = test
    params0, apply, loss_fn, acc = make_mlp(k2, 10, (32,), 5)
    grad_fn = jax.jit(jax.grad(loss_fn))

    adj = np.asarray(adjacency("cycle", n))
    pos = place_nodes(k3, n, chan)
    rng = np.random.default_rng(0)

    evs = event_list(rng, n, horizon, lam_grad, lam_tx, unify_period)
    print(f"== event-driven DRACO: {len(evs)} events over {horizon}s, {n} clients ==")

    params = [jax.tree_util.tree_map(lambda x: x.copy(), params0) for _ in range(n)]
    pending = [jax.tree_util.tree_map(jnp.zeros_like, params0) for _ in range(n)]
    inflight = []  # (arrive_t, dst, weight, delta)
    accepted = np.zeros(n, int)
    period_start = 0.0
    lr, bs = 0.1, 32
    stats = {"grad": 0, "tx": 0, "delivered": 0, "dropped_deadline": 0,
             "dropped_psi": 0, "unify": 0}

    for ev in evs:
        # deliveries due before this event
        for msg in [m for m in inflight if m[0] <= ev.t]:
            inflight.remove(msg)
            _, dst, w, delta = msg
            if accepted[dst] >= psi:
                stats["dropped_psi"] += 1
                continue
            params[dst] = jax.tree_util.tree_map(
                lambda p, d: p + w * d, params[dst], delta)
            accepted[dst] += 1
            stats["delivered"] += 1

        if ev.t - period_start >= unify_period:
            accepted[:] = 0
            period_start += unify_period

        i = ev.client
        if ev.kind == "grad":
            idx = rng.integers(0, xs.shape[1], size=bs)
            g = grad_fn(params[i], xs[i, idx], ys[i, idx])
            delta = jax.tree_util.tree_map(lambda gg: -lr * gg, g)
            pending[i] = jax.tree_util.tree_map(lambda a, b: a + b, pending[i], delta)
            stats["grad"] += 1
        elif ev.kind == "tx":
            tx_mask = jnp.zeros(n, bool).at[i].set(True)
            gamma, succ = transmission_delays(
                jax.random.fold_in(key, int(ev.t * 1e3) % (2**31)), pos, tx_mask, chan)
            nbrs = np.where(adj[i])[0]
            w = 1.0 / max(len(nbrs), 1)  # row-stochastic split
            for j in nbrs:
                if bool(succ[i, j]):
                    inflight.append((ev.t + float(gamma[i, j]), int(j), w, pending[i]))
                else:
                    stats["dropped_deadline"] += 1
            pending[i] = jax.tree_util.tree_map(jnp.zeros_like, pending[i])
            stats["tx"] += 1
        elif ev.kind == "unify":
            for j in range(n):
                if j != i:
                    params[j] = jax.tree_util.tree_map(lambda x: x.copy(), params[i])
            stats["unify"] += 1

    accs = [float(acc(p, tx_t, ty_t)) for p in params]
    print(f"events: {stats}")
    print(f"final mean client accuracy: {np.mean(accs):.3f} (std {np.std(accs):.4f})")
    assert np.mean(accs) > 0.3

    # --- cross-check: the compiled windowed engine on the same setup ------
    from repro.api import simulate
    from repro.core.protocol import DracoConfig

    cfg = DracoConfig(num_clients=n, lr=lr, local_batches=1, batch_size=bs,
                      lambda_grad=lam_grad, lambda_tx=lam_tx,
                      unify_period=int(unify_period), psi=psi,
                      topology="cycle", max_delay_windows=4, channel=chan)
    st, trace = simulate("draco", cfg, params0, loss_fn, train,
                         num_steps=int(horizon), key=key,
                         eval_every=int(horizon) // 4,
                         eval_fn=acc, eval_data=test)
    w_acc = float(trace.metrics["accuracy"][-1])
    print(f"compiled windowed engine (repro.api.simulate, {int(horizon)} "
          f"windows): mean client accuracy {w_acc:.3f}")
    assert w_acc > 0.3


if __name__ == "__main__":
    main()
