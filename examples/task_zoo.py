"""Task zoo tour: one algorithm, four workloads, three optimizers.

Runs DRACO over the wireless-free cycle graph on every registered task
(`linear-softmax`, `mlp`, `small-cnn`, `tiny-lm`), each as ONE compiled
`simulate()` call with the task's metric sampled in-jit — then swaps
the local optimizer on the MLP task (sgd / momentum / adamw) to show
the per-client optimizer state riding the flat plane.

  PYTHONPATH=src python examples/task_zoo.py
"""
import jax

from repro.api import simulate
from repro.core.protocol import DracoConfig
from repro.tasks import get_task, list_tasks, opt_width

N = 16
WINDOWS = 120
cfg = DracoConfig(num_clients=N, lr=0.05, lambda_grad=0.5, lambda_tx=0.5,
                  unify_period=50, psi=0, topology="cycle",
                  max_delay_windows=4)
key = jax.random.PRNGKey(0)

print(f"== every task, DRACO, N={N}, {WINDOWS} windows ==")
print("task,metric,start,end")
for name in list_tasks():
    task = get_task(name)
    _, trace = simulate("draco", cfg.replace(lr=0.01 if name == "tiny-lm"
                                             else 0.05),
                        task=task, num_steps=WINDOWS, key=key,
                        eval_every=WINDOWS // 2)
    m = trace.metrics[task.metric_name]
    print(f"{name},{task.metric_name},{float(m[0]):.4f},{float(m[-1]):.4f}")

print("\n== optimizer axis on the mlp task (state on the flat plane) ==")
print("optimizer,Dopt,final_acc")
for opt in ("sgd", "momentum", "adamw"):
    task = get_task("mlp", optimizer=opt)
    # momentum's effective step is ~1/(1-beta) larger; adamw is scale-free
    lr = {"sgd": 0.05, "momentum": 0.01, "adamw": 0.005}[opt]
    st, trace = simulate("draco", cfg.replace(lr=lr), task=task,
                         num_steps=WINDOWS, key=key, eval_every=WINDOWS)
    dopt = opt_width(task, task.init_params(jax.random.PRNGKey(0)))
    assert st.opt_state.shape == (N, dopt)
    print(f"{opt},{dopt},{float(trace.metrics['accuracy'][-1]):.4f}")
