"""End-to-end driver: DRACO-train an assigned-architecture LM.

Default: a reduced qwen2-family model, 4 clients, 200 steps on CPU —
demonstrates the full production path (model zoo -> DRACO window step ->
gossip mixing -> unification -> checkpointing).

For a ~100M-parameter run on real hardware:
  python examples/train_lm_federated.py --hundred-m --steps 300 --clients 8

  PYTHONPATH=src python examples/train_lm_federated.py
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--hundred-m", action="store_true",
                    help="~100M-param config (needs accelerators)")
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    argv = [
        "--arch", args.arch, "--steps", str(args.steps),
        "--clients", str(args.clients), "--seq", str(args.seq),
        "--batch-per-client", "2", "--mix", "dense", "--psi", "2",
        "--unify-every", "50", "--ckpt-dir", "/tmp/repro_lm_ckpt",
        "--ckpt-every", "100", "--log-every", "20",
    ]
    if not args.hundred_m:
        argv.append("--reduced")
    losses = train_main(argv)
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} DRACO windows")


if __name__ == "__main__":
    main()
