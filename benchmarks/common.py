"""Shared benchmark utilities."""
import json
import time

import jax

# name -> us_per_call for everything emitted this process; written out as
# BENCH_gossip.json by benchmarks.run so the perf trajectory is tracked
# across PRs (CI uploads it as an artifact).
RESULTS = {}


def time_fn(fn, *args, warmup=2, iters=10):
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name, us, derived=""):
    RESULTS[name] = us
    print(f"{name},{us:.1f},{derived}")


def write_json(path="BENCH_gossip.json"):
    """Machine-readable mirror of the CSV: {name: us_per_call}."""
    with open(path, "w") as f:
        json.dump(RESULTS, f, indent=1, sort_keys=True)
    print(f"# wrote {path} ({len(RESULTS)} entries)")
    return path
