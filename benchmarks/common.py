"""Shared benchmark utilities."""
import time

import jax


def time_fn(fn, *args, warmup=2, iters=10):
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
