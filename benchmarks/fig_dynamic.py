"""Dynamic-scenario sweeps: accuracy/consensus vs churn and stragglers.

Two Fig.-4-style sweeps over the scenario engine, DRACO on the EMNIST-
like task with the paper wireless channel:

  - **churn sweep** — `markov-edge-flip` at increasing per-step edge
    flip rates (churn=0 is the frozen graph, the delayed-update analysis
    regime where link-staleness *distribution* drives convergence);
  - **straggler sweep** — `straggler-profile` at increasing straggler
    fractions (10x heavy-tailed slowdowns, 50% duty cycles), probing the
    paper's "manageable instructions for stragglers" claim under the
    decoupled computation schedule.

Each point is ONE fused `repro.api.simulate` call with in-jit accuracy +
consensus sampling. Writes `results/fig_dynamic_{task}.json` and mirrors
final-point scalars to `BENCH_scenarios.json` (uploaded as a CI artifact
next to `BENCH_gossip.json`, so the scenario-robustness trajectory is
tracked across PRs).

  PYTHONPATH=src python -m benchmarks.fig_dynamic --task emnist
  PYTHONPATH=src python -m benchmarks.fig_dynamic --quick   # CI-sized
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from benchmarks.fig3_convergence import setup
from repro.api import make_context, simulate

CHURNS = (0.0, 0.05, 0.2, 0.5)
FRACS = (0.0, 0.2, 0.5)


def _one_run(salt, cfg, params0, loss, train, test, acc, key, windows,
             segments, scenario, scenario_kwargs):
    ctx = make_context(cfg, loss, train, params0=params0, scenario=scenario,
                       scenario_key=jax.random.fold_in(key, salt),
                       scenario_kwargs=scenario_kwargs)
    seg_w = max(1, windows // segments)
    st, trace = simulate("draco", cfg, params0, loss, train,
                         num_steps=segments * seg_w, key=key,
                         eval_every=seg_w, eval_fn=acc, eval_data=test,
                         ctx=ctx)
    accs = [float(a) for a in trace.metrics["accuracy"]]
    cons = [float(c) for c in trace.metrics["consensus"]]
    return {
        "final_acc": accs[-1],
        "best_acc": max(accs),
        "final_consensus": cons[-1],
        "acc_curve": accs,
        "consensus_curve": cons,
        "msgs": int(st.total_accept.sum()),
    }


def run(task_name="emnist", windows=240, segments=6, seed=0, num_clients=None,
        churns=CHURNS, fracs=FRACS, sched_steps=32, out_dir="results",
        bench_json="BENCH_scenarios.json", quick=False):
    if quick:
        windows, segments, num_clients = 60, 3, num_clients or 8
        churns, fracs, sched_steps = (0.0, 0.2), (0.0, 0.5), 12
    cfg, train, test, params0, loss, acc, key = setup(task_name, seed,
                                                      num_clients)
    results = {"churn": {}, "straggler": {}}
    for i, churn in enumerate(churns):
        results["churn"][float(churn)] = _one_run(
            i, cfg, params0, loss, train, test, acc, key,
            windows, segments, "markov-edge-flip",
            {"steps": sched_steps, "churn": float(churn)})
    for i, frac in enumerate(fracs):
        results["straggler"][float(frac)] = _one_run(
            100 + i, cfg, params0, loss, train, test, acc, key,
            windows, segments, "straggler-profile",
            {"steps": sched_steps, "straggler_frac": float(frac),
             "slowdown": 10.0, "duty": 0.5})

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"fig_dynamic_{task_name}.json")
    with open(path, "w") as f:
        json.dump({"task": task_name, "windows": windows,
                   "results": results}, f, indent=1)
    print(f"# Fig-dynamic scenario sweeps ({task_name}) -> {path}")
    print("sweep,knob,final_acc,best_acc,final_consensus,msgs")
    bench = {}
    for sweep, rows in results.items():
        for knob, r in rows.items():
            print(f"{sweep},{knob},{r['final_acc']:.4f},{r['best_acc']:.4f},"
                  f"{r['final_consensus']:.4f},{r['msgs']}")
            tag = f"scenario_{sweep}_{knob}"
            bench[f"{tag}_final_acc"] = r["final_acc"]
            bench[f"{tag}_final_consensus"] = r["final_consensus"]
    if bench_json:
        with open(bench_json, "w") as f:
            json.dump(bench, f, indent=1, sort_keys=True)
        print(f"# wrote {bench_json} ({len(bench)} entries)")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="emnist")
    ap.add_argument("--windows", type=int, default=240)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.task, windows=a.windows, seed=a.seed, num_clients=a.clients,
        quick=a.quick)
