"""Dynamic-scenario sweeps: accuracy/consensus vs churn and stragglers.

Two Fig.-4-style sweeps over the scenario engine, DRACO on the EMNIST-
like task with the paper wireless channel:

  - **churn sweep** — `markov-edge-flip` at increasing per-step edge
    flip rates (churn=0 is the frozen graph, the delayed-update analysis
    regime where link-staleness *distribution* drives convergence);
  - **straggler sweep** — `straggler-profile` at increasing straggler
    fractions (10x heavy-tailed slowdowns, 50% duty cycles), probing the
    paper's "manageable instructions for stragglers" claim under the
    decoupled computation schedule.

Each sweep family is ONE compiled `repro.api.simulate_sweep` call: the
per-point schedules are tree-stacked along the scanned scenario axis
(same ring shapes within a family), seeds ride the vmapped axis, and
accuracy + consensus sample in-jit. Writes
`results/fig_dynamic_{task}.json` and mirrors final-point scalars to
`BENCH_scenarios.json` (uploaded as a CI artifact next to
`BENCH_gossip.json`, so the scenario-robustness trajectory is tracked
across PRs).

  PYTHONPATH=src python -m benchmarks.fig_dynamic --task emnist
  PYTHONPATH=src python -m benchmarks.fig_dynamic --quick   # CI-sized
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from benchmarks.fig3_convergence import seed_keys, setup
from repro.api import make_context, simulate_sweep
from repro.scenarios import make_schedule

CHURNS = (0.0, 0.05, 0.2, 0.5)
FRACS = (0.0, 0.2, 0.5)


def _total_accept(state):
    return state.total_accept


def _sweep_family(cfg, params0, loss, train, test, acc, key, keys, windows,
                  segments, scenario, salts, kwargs_list, ctx,
                  metric="accuracy"):
    """One scenario family (shared generator, varying knobs) as one
    sweep call over the stacked-schedule grid axis. `loss` is the
    workload slot: a bare loss callable or a `repro.tasks.Task` (whose
    metric name arrives via `metric`)."""
    scheds = [make_schedule(scenario, cfg, key=jax.random.fold_in(key, salt),
                            **kw) for salt, kw in zip(salts, kwargs_list)]
    seg_w = max(1, windows // segments)
    accepted, trace = simulate_sweep(
        "draco", cfg, params0, loss, train, num_steps=segments * seg_w,
        keys=keys, eval_every=seg_w, eval_fn=acc, eval_data=test,
        schedules=scheds, ctx=ctx, final_fn=_total_accept)
    best = min if metric == "perplexity" else max  # lower ppl is better
    rows = []
    for g in range(len(scheds)):
        accs = [float(a) for a in
                np.asarray(trace.metrics[metric][g]).mean(axis=0)]
        cons = [float(c) for c in
                np.asarray(trace.metrics["consensus"][g]).mean(axis=0)]
        rows.append({
            "final_acc": accs[-1],
            "best_acc": best(accs),
            "final_consensus": cons[-1],
            "acc_curve": accs,
            "consensus_curve": cons,
            "msgs": int(np.asarray(accepted[g]).sum(axis=-1).mean()),
        })
    return rows


def run(task_name="emnist", windows=240, segments=6, seed=0, num_clients=None,
        churns=CHURNS, fracs=FRACS, sched_steps=32, out_dir="results",
        bench_json="BENCH_scenarios.json", quick=False, seeds=1,
        optimizer="sgd"):
    from repro.tasks import is_task

    if quick:
        windows, segments, num_clients = 60, 3, num_clients or 8
        churns, fracs, sched_steps = (0.0, 0.2), (0.0, 0.5), 12
    cfg, train, test, params0, loss, acc, key = setup(task_name, seed,
                                                      num_clients,
                                                      optimizer=optimizer)
    metric = loss.metric_name if is_task(loss) else "accuracy"
    ctx = make_context(cfg, loss, train, params0=params0)
    keys = seed_keys(key, seeds)
    churn_rows = _sweep_family(
        cfg, params0, loss, train, test, acc, key, keys, windows, segments,
        "markov-edge-flip", range(len(churns)),
        [{"steps": sched_steps, "churn": float(c)} for c in churns], ctx,
        metric=metric)
    strag_rows = _sweep_family(
        cfg, params0, loss, train, test, acc, key, keys, windows, segments,
        "straggler-profile", [100 + i for i in range(len(fracs))],
        [{"steps": sched_steps, "straggler_frac": float(f),
          "slowdown": 10.0, "duty": 0.5} for f in fracs], ctx,
        metric=metric)
    results = {
        "churn": {float(c): r for c, r in zip(churns, churn_rows)},
        "straggler": {float(f): r for f, r in zip(fracs, strag_rows)},
    }

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"fig_dynamic_{task_name}.json")
    with open(path, "w") as f:
        json.dump({"task": task_name, "windows": windows,
                   "metric": metric, "results": results}, f, indent=1)
    print(f"# Fig-dynamic scenario sweeps ({task_name}) -> {path}")
    print(f"sweep,knob,final_{metric},best_{metric},final_consensus,msgs")
    bench = {}
    for sweep, rows in results.items():
        for knob, r in rows.items():
            print(f"{sweep},{knob},{r['final_acc']:.4f},{r['best_acc']:.4f},"
                  f"{r['final_consensus']:.4f},{r['msgs']}")
            tag = f"scenario_{sweep}_{knob}"
            bench[f"{tag}_final_acc"] = r["final_acc"]
            bench[f"{tag}_final_consensus"] = r["final_consensus"]
    if bench_json:
        with open(bench_json, "w") as f:
            json.dump(bench, f, indent=1, sort_keys=True)
        print(f"# wrote {bench_json} ({len(bench)} entries)")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="emnist",
                    help="paper preset (emnist/poker) or task-registry "
                         "workload (linear-softmax/mlp/small-cnn/tiny-lm)")
    ap.add_argument("--optimizer", default="sgd",
                    choices=("sgd", "momentum", "adamw"))
    ap.add_argument("--windows", type=int, default=240)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.task, windows=a.windows, seed=a.seed, num_clients=a.clients,
        quick=a.quick, seeds=a.seeds, optimizer=a.optimizer)
