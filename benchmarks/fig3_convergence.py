"""Paper Fig. 3: DRACO vs the four baselines over unreliable wireless.

(a) EMNIST-like task, cycle topology; (b) Poker-like task, complete
topology. Writes a CSV of accuracy-vs-events curves to results/ and
prints the final table.

  PYTHONPATH=src python -m benchmarks.fig3_convergence --task emnist
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.draco_paper import TASKS
from repro.core.baselines import BASELINES, eval_params, init_baseline_state, run_baseline
from repro.core.channel import ChannelConfig
from repro.core.protocol import DracoConfig, build_graph, init_state, run_windows
from repro.data.synthetic import federated_classification, make_mlp


def setup(task_name: str, seed: int = 0, num_clients: int = None):
    t = TASKS[task_name]
    n = num_clients or t.num_clients
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    train, test = federated_classification(
        k1, n, input_dim=t.input_dim, num_classes=t.num_classes,
        per_client=t.samples_per_client)
    params0, apply, loss, acc = make_mlp(k2, t.input_dim, t.hidden, t.num_classes)
    topology = "cycle" if task_name == "emnist" else "complete"
    chan = ChannelConfig(message_bytes=t.message_bytes, gamma_max=10.0)
    # psi scales with in-degree (fig4 sweeps it explicitly); cycle has 2
    # in-neighbors, complete has n-1 — a fixed tiny cap starves complete.
    psi = 6 if topology == "cycle" else 0
    cfg = DracoConfig(num_clients=n, lr=t.lr, local_batches=t.local_batches,
                      batch_size=t.batch_size, lambda_grad=t.lambda_grad,
                      lambda_tx=t.lambda_grad, unify_period=50, psi=psi,
                      topology=topology, max_delay_windows=4, channel=chan)
    return cfg, train, test, params0, loss, acc, k3


def run(task_name="emnist", segments=8, seg_windows=100, seg_rounds=None,
        seed=0, num_clients=None, out_dir="results"):
    """Compute-matched comparison: every method gets the same expected
    number of local gradient computations per client per segment.
    DRACO does p_grad = 1-exp(-lambda*w) grads/client/window; sync
    baselines do 1 grad/client/round; async baselines ~p_active=0.5."""
    cfg, train, test, params0, loss, acc, key = setup(task_name, seed, num_clients)
    tx_, ty_ = test
    mean_acc = lambda params: float(
        jax.vmap(lambda p: acc(p, tx_, ty_))(params).mean())

    p_grad = 1.0 - np.exp(-cfg.lambda_grad * cfg.window)
    rounds_sync = seg_rounds or max(1, int(round(seg_windows * p_grad)))
    rounds_async = seg_rounds or max(1, int(round(seg_windows * p_grad / 0.5)))

    curves = {}
    # --- DRACO ------------------------------------------------------------
    q, adj = build_graph(cfg)
    st = init_state(key, cfg, params0)
    curve = [mean_acc(st.params)]
    for _ in range(segments):
        st = run_windows(st, cfg, q, adj, loss, train, seg_windows)
        curve.append(mean_acc(st.params))
    curves["draco"] = curve

    # --- baselines ----------------------------------------------------------
    for m in BASELINES:
        r = rounds_sync if m.startswith("sync") else rounds_async
        bst = init_baseline_state(key, cfg, params0)
        curve = [mean_acc(bst.params)]
        for _ in range(segments):
            bst = run_baseline(m, bst, cfg, loss, train, r)
            curve.append(mean_acc(eval_params(m, bst)))
        curves[m] = curve

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"fig3_{task_name}.json")
    with open(path, "w") as f:
        json.dump({"task": task_name, "topology": cfg.topology,
                   "curves": curves}, f, indent=1)
    print(f"# Fig3 ({task_name}, {cfg.topology} topology) -> {path}")
    print("method,final_acc,best_acc")
    for m, c in curves.items():
        print(f"{m},{c[-1]:.4f},{max(c):.4f}")
    return curves


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="emnist", choices=list(TASKS))
    ap.add_argument("--segments", type=int, default=8)
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    run(a.task, segments=a.segments, seed=a.seed, num_clients=a.clients)
