"""Paper Fig. 3: DRACO vs the four baselines over unreliable wireless.

(a) EMNIST-like task, cycle topology; (b) Poker-like task, complete
topology. Writes a CSV of accuracy-vs-events curves to results/ and
prints the final table.

Runs on the batched sweep engine (`repro.api.simulate_sweep`): every
method's whole seed batch is ONE compiled device call — the per-seed
states are vmapped through the fused nested scan with in-jit eval, so
adding seeds costs batched GEMMs, not extra dispatches. `--seeds 1`
reproduces the single-seed curves bit-for-bit (row 0 of a seed sweep
equals the solo `simulate()` run; tests/test_sweep.py pins this).

  PYTHONPATH=src python -m benchmarks.fig3_convergence --task emnist --seeds 4
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.api import get_algorithm, make_context, simulate_sweep, steps_for_budget
from repro.configs.draco_paper import TASKS
from repro.core.baselines import BASELINES
from repro.core.channel import ChannelConfig
from repro.core.protocol import DracoConfig


def setup(task_name: str, seed: int = 0, num_clients: int = None,
          optimizer: str = "sgd"):
    """Build (cfg, train, test, params0, workload, eval_fn, key).

    `task_name` is either a paper preset (`TASKS`: "emnist"/"poker" —
    the pre-task-layer make_mlp path, bit-for-bit) or a `repro.tasks`
    registry name ("linear-softmax", "mlp", "small-cnn", "tiny-lm").
    For registry tasks the returned workload slot is the `Task` itself
    (feed it to `simulate`'s loss position or `task=`), `optimizer`
    selects its local update rule, and the wireless message size is
    derived from the model's actual f32 byte count.
    """
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    if task_name in TASKS:
        from repro.data.synthetic import federated_classification, make_mlp

        t = TASKS[task_name]
        n = num_clients or t.num_clients
        train, test = federated_classification(
            k1, n, input_dim=t.input_dim, num_classes=t.num_classes,
            per_client=t.samples_per_client)
        params0, apply, loss, acc = make_mlp(k2, t.input_dim, t.hidden,
                                             t.num_classes)
        if optimizer != "sgd":
            raise ValueError(
                f"paper preset {task_name!r} is the legacy plain-SGD "
                "path; use a task-registry name to swap optimizers")
        workload, eval_fn = loss, acc
        topology = "cycle" if task_name == "emnist" else "complete"
        message_bytes, lr = t.message_bytes, t.lr
        local_batches, batch_size, lambda_grad = (t.local_batches,
                                                  t.batch_size, t.lambda_grad)
    else:
        from repro.tasks import get_task

        task = get_task(task_name, optimizer=optimizer)
        n = num_clients or 25
        params0, train, test = task.setup(
            jax.random.fold_in(jax.random.PRNGKey(seed), 1), n)
        workload, eval_fn = task, task.eval_fn
        topology = "cycle"
        message_bytes = 4 * sum(
            int(np.prod(np.shape(l)))
            for l in jax.tree_util.tree_leaves(params0))
        lr, local_batches, batch_size, lambda_grad = 0.05, 1, 64, 0.1
    chan = ChannelConfig(message_bytes=message_bytes, gamma_max=10.0)
    # psi scales with in-degree (fig4 sweeps it explicitly); cycle has 2
    # in-neighbors, complete has n-1 — a fixed tiny cap starves complete.
    psi = 6 if topology == "cycle" else 0
    cfg = DracoConfig(num_clients=n, lr=lr, local_batches=local_batches,
                      batch_size=batch_size, lambda_grad=lambda_grad,
                      lambda_tx=lambda_grad, unify_period=50, psi=psi,
                      topology=topology, max_delay_windows=4, channel=chan)
    return cfg, train, test, params0, workload, eval_fn, k3


def seed_keys(key, seeds: int):
    """The sweep's stacked key rows: `seeds == 1` keeps the base key
    itself (bit-for-bit the pre-sweep single-run behavior), more seeds
    split it."""
    return key[None] if seeds <= 1 else jax.random.split(key, seeds)


def _discard(state):
    """final_fn: the figure only reads the trace."""
    return ()


def run(task_name="emnist", segments=8, seg_windows=100, seg_rounds=None,
        seed=0, num_clients=None, out_dir="results", seeds=1,
        optimizer="sgd"):
    """Compute-matched comparison: every method gets the same expected
    local compute per client per segment (`steps_for_budget`; for task-
    registry workloads the budget is priced in FLOPs via
    `task.grad_cost`). Each method's seed batch runs as a single
    vmapped `simulate_sweep(...)` scan sampling the task metric in-jit;
    curves are seed-means."""
    from repro.tasks import is_task

    cfg, train, test, params0, workload, eval_fn, key = setup(
        task_name, seed, num_clients, optimizer=optimizer)
    keys = seed_keys(key, seeds)
    task = workload if is_task(workload) else None
    metric = task.metric_name if task is not None else "accuracy"

    # per-segment compute budget = DRACO's expected compute over one
    # segment (FLOP-priced through task.grad_cost for registry tasks)
    cost = task.grad_cost if task is not None else 1.0
    budget = seg_windows * get_algorithm("draco").grads_per_step(cfg) * cost

    # one shared context: graph, weight matrices and flat-plane layout
    # built once for all methods
    ctx = make_context(cfg, workload, train, params0=params0)
    # every method starts from params0 replicated across clients (and
    # push weights of 1), so the step-0 metric is one plain eval
    m0 = float(eval_fn(params0, test[0], test[1]))
    curves = {}
    for name in ("draco",) + tuple(BASELINES):
        algo = get_algorithm(name)
        if name == "draco":
            per_seg = seg_windows
        else:
            per_seg = seg_rounds or steps_for_budget(name, cfg, budget,
                                                     task=task)
        _, trace = simulate_sweep(algo, cfg, params0, workload, train,
                                  num_steps=segments * per_seg, keys=keys,
                                  eval_every=per_seg, eval_fn=eval_fn,
                                  eval_data=test, ctx=ctx, final_fn=_discard)
        seed_mean = np.asarray(trace.metrics[metric][0]).mean(axis=0)
        curves[name] = [m0] + [float(a) for a in seed_mean]

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"fig3_{task_name}.json")
    with open(path, "w") as f:
        json.dump({"task": task_name, "topology": cfg.topology,
                   "metric": metric, "curves": curves}, f, indent=1)
    print(f"# Fig3 ({task_name}, {cfg.topology} topology, {seeds} seed(s)) -> {path}")
    print(f"method,final_{metric},best_{metric}")
    best = min if metric == "perplexity" else max
    for m, c in curves.items():
        print(f"{m},{c[-1]:.4f},{best(c):.4f}")
    return curves


if __name__ == "__main__":
    from repro.tasks import list_tasks

    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="emnist",
                    choices=list(TASKS) + list(list_tasks()),
                    help="paper preset (emnist/poker) or task-registry "
                         "workload (linear-softmax/mlp/small-cnn/tiny-lm)")
    ap.add_argument("--optimizer", default="sgd",
                    choices=("sgd", "momentum", "adamw"),
                    help="local update rule (task-registry workloads only)")
    ap.add_argument("--segments", type=int, default=8)
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--seeds", type=int, default=1,
                    help="seed rows of the vmapped sweep (curves are means)")
    a = ap.parse_args()
    run(a.task, segments=a.segments, seed=a.seed, num_clients=a.clients,
        seeds=a.seeds, optimizer=a.optimizer)
