"""Paper Fig. 4: effect of the Psi message cap (Gamma_max = 10).

Sweeps Psi and reports accuracy + communication cost (accepted messages).
Expected trends (paper Sec. 5): tiny Psi starves aggregation and slows
learning; very large Psi wastes communication with no accuracy gain and
can oscillate.

  PYTHONPATH=src python -m benchmarks.fig4_psi_sweep --task emnist
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.fig3_convergence import setup
from repro.core.protocol import build_graph, init_state, run_windows


def run(task_name="emnist", psis=(1, 2, 4, 8, 24), windows=600, seed=0,
        num_clients=None, out_dir="results"):
    cfg0, train, test, params0, loss, acc, key = setup(task_name, seed, num_clients)
    tx_, ty_ = test
    results = {}
    for psi in psis:
        cfg = cfg0.replace(psi=int(psi))
        q, adj = build_graph(cfg)
        st = init_state(key, cfg, params0)
        accs = []
        msgs = 0
        for seg in range(6):
            prev_cnt = int(st.accept_count.sum())
            st = run_windows(st, cfg, q, adj, loss, train, windows // 6)
            accs.append(float(jax.vmap(lambda p: acc(p, tx_, ty_))(st.params).mean()))
            msgs += int(st.accept_count.sum())
        results[int(psi)] = {
            "final_acc": accs[-1],
            "best_acc": max(accs),
            "acc_curve": accs,
            "osc": float(jnp.std(jnp.diff(jnp.asarray(accs[2:])))) if len(accs) > 3 else 0.0,
        }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"fig4_{task_name}.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1)
    print(f"# Fig4 Psi sweep ({task_name}) -> {path}")
    print("psi,final_acc,best_acc,oscillation")
    for psi, r in results.items():
        print(f"{psi},{r['final_acc']:.4f},{r['best_acc']:.4f},{r['osc']:.4f}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="emnist")
    ap.add_argument("--windows", type=int, default=600)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    run(a.task, windows=a.windows, seed=a.seed)
