"""Paper Fig. 4: effect of the Psi message cap (Gamma_max = 10).

Sweeps Psi and reports accuracy + communication cost (accepted messages).
Expected trends (paper Sec. 5): tiny Psi starves aggregation and slows
learning; very large Psi wastes communication with no accuracy gain and
can oscillate.

Each Psi point is ONE fused `repro.api.simulate` call with in-jit
accuracy sampling (`eval_every`) — no per-segment host round-trips.

  PYTHONPATH=src python -m benchmarks.fig4_psi_sweep --task emnist
"""
from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp

from benchmarks.fig3_convergence import setup
from repro.api import make_context, simulate


def run(task_name="emnist", psis=(1, 2, 4, 8, 24), windows=600, seed=0,
        num_clients=None, out_dir="results", segments=6):
    cfg0, train, test, params0, loss, acc, key = setup(task_name, seed, num_clients)
    seg_w = max(1, windows // segments)
    # graph/weights/flat layout built once; per-psi runs rebind only the
    # static config
    ctx0 = make_context(cfg0, loss, train, params0=params0)
    results = {}
    for psi in psis:
        cfg = cfg0.replace(psi=int(psi))
        st, trace = simulate("draco", cfg, params0, loss, train,
                             num_steps=segments * seg_w, key=key,
                             eval_every=seg_w, eval_fn=acc, eval_data=test,
                             ctx=ctx0.replace(cfg=cfg))
        accs = [float(a) for a in trace.metrics["accuracy"]]
        results[int(psi)] = {
            "final_acc": accs[-1],
            "best_acc": max(accs),
            "acc_curve": accs,
            "msgs": int(st.total_accept.sum()),
            "osc": float(jnp.std(jnp.diff(jnp.asarray(accs[2:])))) if len(accs) > 3 else 0.0,
        }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"fig4_{task_name}.json")
    with open(path, "w") as f:
        json.dump(results, f, indent=1)
    print(f"# Fig4 Psi sweep ({task_name}) -> {path}")
    print("psi,final_acc,best_acc,oscillation")
    for psi, r in results.items():
        print(f"{psi},{r['final_acc']:.4f},{r['best_acc']:.4f},{r['osc']:.4f}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="emnist")
    ap.add_argument("--windows", type=int, default=600)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    run(a.task, windows=a.windows, seed=a.seed)
