"""Paper Fig. 4: effect of the Psi message cap (Gamma_max = 10).

Sweeps Psi and reports accuracy + communication cost (accepted messages).
Expected trends (paper Sec. 5): tiny Psi starves aggregation and slows
learning; very large Psi wastes communication with no accuracy gain and
can oscillate.

The WHOLE grid — every Psi point x every seed — is ONE compiled
`repro.api.simulate_sweep` call: Psi rides the scanned config axis as a
*traced* override (one trace for the whole sweep, no per-Psi recompile),
seeds ride the vmapped axis, and accuracy samples in-jit. Each grid cell
is bit-for-bit the solo `simulate()` run with that (Psi, seed).

  PYTHONPATH=src python -m benchmarks.fig4_psi_sweep --task emnist --seeds 4
"""
from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.fig3_convergence import seed_keys, setup
from repro.api import make_context, simulate_sweep


def _total_accept(state):
    """final_fn: the sweep only needs the per-run message counters."""
    return state.total_accept


def run(task_name="emnist", psis=(1, 2, 4, 8, 24), windows=600, seed=0,
        num_clients=None, out_dir="results", segments=6, seeds=1,
        optimizer="sgd"):
    from repro.tasks import is_task

    cfg0, train, test, params0, loss, acc, key = setup(task_name, seed,
                                                       num_clients,
                                                       optimizer=optimizer)
    metric = loss.metric_name if is_task(loss) else "accuracy"
    seg_w = max(1, windows // segments)
    grid = [cfg0.replace(psi=int(p)) for p in psis]
    # graph/weights/flat layout built once; the sweep re-binds psi as a
    # traced scalar per scanned grid row
    ctx = make_context(grid[0], loss, train, params0=params0)
    keys = seed_keys(key, seeds)
    accepted, trace = simulate_sweep(
        "draco", grid, params0, loss, train, num_steps=segments * seg_w,
        keys=keys, eval_every=seg_w, eval_fn=acc, eval_data=test, ctx=ctx,
        final_fn=_total_accept)  # accepted: (G, K, N)

    best = min if metric == "perplexity" else max  # lower ppl is better
    results = {}
    for g, psi in enumerate(psis):
        accs = [float(a) for a in
                np.asarray(trace.metrics[metric][g]).mean(axis=0)]
        results[int(psi)] = {
            "final_acc": accs[-1],
            "best_acc": best(accs),
            "acc_curve": accs,
            "msgs": int(np.asarray(accepted[g]).sum(axis=-1).mean()),
            "osc": float(jnp.std(jnp.diff(jnp.asarray(accs[2:])))) if len(accs) > 3 else 0.0,
        }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"fig4_{task_name}.json")
    with open(path, "w") as f:
        # "metric" names what final_acc/best_acc actually hold (fig3's
        # convention): "perplexity" rows rank lower-is-better
        json.dump({"task": task_name, "metric": metric,
                   "results": results}, f, indent=1)
    print(f"# Fig4 Psi sweep ({task_name}, {seeds} seed(s)) -> {path}")
    print(f"psi,final_{metric},best_{metric},oscillation")
    for psi, r in results.items():
        print(f"{psi},{r['final_acc']:.4f},{r['best_acc']:.4f},{r['osc']:.4f}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="emnist",
                    help="paper preset (emnist/poker) or task-registry "
                         "workload (linear-softmax/mlp/small-cnn/tiny-lm)")
    ap.add_argument("--optimizer", default="sgd",
                    choices=("sgd", "momentum", "adamw"))
    ap.add_argument("--windows", type=int, default=600)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--seeds", type=int, default=1)
    a = ap.parse_args()
    run(a.task, windows=a.windows, seed=a.seed, seeds=a.seeds,
        optimizer=a.optimizer)
