"""Benchmark harness — one function per paper table/figure.

Protocol-level benches run through the unified ``repro.api`` interface
(``simulate`` + the algorithm registry); ``bench_simulate_fused`` tracks
the in-jit-eval speedup of the fused driver vs the legacy segment loop.

Prints ``name,us_per_call,derived`` CSV and mirrors the timings to
``BENCH_gossip.json`` (name -> us_per_call; uploaded as a CI artifact so
the perf trajectory is tracked across PRs). Measured numbers and knob
guidance live in EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.run            # full set
  PYTHONPATH=src python -m benchmarks.run --quick    # CI-sized
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn


def bench_gossip_mix(quick=False):
    """Kernel-layer: row-stochastic mixing at paper scale (25 clients,
    0.57 MB model = ~149k f32 params)."""
    from repro.core.mixing import mix_dense
    from repro.kernels.gossip.ops import gossip_mix

    n, d = 25, 149_194
    key = jax.random.PRNGKey(0)
    q = jax.nn.softmax(jax.random.normal(key, (n, n)))
    deltas = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    f = jax.jit(lambda q, x: mix_dense(q, {"w": x})["w"])
    us = time_fn(f, q, deltas)
    emit("gossip_mix_xla_25x149k", us, f"{n*n*d*2/us*1e6/1e9:.1f}GFLOPs")
    if not quick:
        # interpret auto-selects by backend: compiled kernel on TPU, the
        # (slow, correctness-only) interpreter elsewhere — hence tiny D.
        # Name the row by what actually ran so cross-machine trajectories
        # never mix interpreter and compiled-kernel timings.
        from repro.kernels.gossip.ops import default_use_kernel

        us_k = time_fn(lambda: gossip_mix(q, deltas[:, :4096]),
                       warmup=1, iters=3)
        if default_use_kernel():
            emit("gossip_mix_pallas_4k", us_k, "kernel-path")
        else:
            emit("gossip_mix_pallas_interpret_4k", us_k, "correctness-path")


def bench_ssd(quick=False):
    """SSD chunked (dual form) vs sequential recurrence — the Mamba2 layer
    speed story on the paper's assigned ssm archs."""
    from repro.models.ssm import ssd_chunked, ssd_reference

    B, T, H, P, G, N = (1, 512, 8, 32, 1, 32) if quick else (2, 1024, 16, 64, 1, 64)
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B_ = jax.random.normal(ks[3], (B, T, G, N))
    C_ = jax.random.normal(ks[4], (B, T, G, N))
    D = jnp.ones((H,))
    f_chunk = jax.jit(lambda *a: ssd_chunked(*a, chunk=128))
    f_seq = jax.jit(ssd_reference)
    us_c = time_fn(f_chunk, x, dt, A, B_, C_, D, iters=5)
    us_s = time_fn(f_seq, x, dt, A, B_, C_, D, iters=5)
    emit("ssd_chunked_T%d" % T, us_c, f"speedup_vs_seq={us_s/us_c:.2f}x")
    emit("ssd_sequential_T%d" % T, us_s, "oracle")


def bench_draco_window(quick=False):
    """Protocol-layer: the fused delay-bucketed gossip engine vs the seed
    per-bucket-einsum loop, at the paper's experiment scale (N=25 clients,
    EMNIST-like MLP ~146k params, wireless channel, deep D=8 ring).

    Both paths are timed per window inside their compiled `run_windows`
    scan — the production shape. The acceptance bar for PR 2 is >= 2x on
    the fused/legacy pair below (see EXPERIMENTS.md for the knob sweep).
    """
    from benchmarks.fig3_convergence import setup
    from repro.core.protocol import (
        build_graph,
        init_state,
        init_state_legacy,
        run_windows,
        run_windows_legacy,
    )

    n = 8 if quick else 25
    D = 4 if quick else 8
    windows = 6 if quick else 16
    iters = 3 if quick else 5
    cfg, train, test, params0, loss, acc, key = setup("emnist", num_clients=n)
    cfg = cfg.replace(max_delay_windows=D)
    q, adj = build_graph(cfg)

    st_f = init_state(key, cfg, params0)
    st_l = init_state_legacy(key, cfg, params0)
    fused = lambda: run_windows(st_f, cfg, q, adj, loss, train, windows)
    legacy = lambda: run_windows_legacy(st_l, cfg, q, adj, loss, train, windows)
    us_f = time_fn(fused, warmup=1, iters=iters) / windows
    us_l = time_fn(legacy, warmup=1, iters=iters) / windows
    emit(f"draco_window_fused_N{n}_D{D}", us_f,
         f"speedup_vs_seed_loop={us_l/us_f:.2f}x")
    emit(f"draco_window_legacy_N{n}_D{D}", us_l, "seed-path")


def bench_simulate_fused(quick=False):
    """API-layer: fused `repro.api.simulate` (one nested scan, in-jit
    eval at each eval point) vs the legacy segment loop (host round-trip
    eval between `run_windows` calls). Same protocol, same eval cadence."""
    from benchmarks.fig3_convergence import setup
    from repro.api import simulate
    from repro.core.protocol import build_graph, init_state, run_windows

    n = 8 if quick else 16
    windows = 60 if quick else 200
    every = 10 if quick else 25
    cfg, train, test, params0, loss, acc, key = setup("emnist", num_clients=n)

    def fused():
        st, trace = simulate("draco", cfg, params0, loss, train,
                             num_steps=windows, key=key, eval_every=every,
                             eval_fn=acc, eval_data=test)
        return st.params

    q, adj = build_graph(cfg)

    def segment_loop():
        st = init_state(key, cfg, params0)
        for _ in range(windows // every):
            st = run_windows(st, cfg, q, adj, loss, train, every)
            float(jax.vmap(lambda p: acc(p, test[0], test[1]))(st.params).mean())
        return st.params

    us_f = time_fn(fused, warmup=1, iters=3)
    us_l = time_fn(segment_loop, warmup=1, iters=3)
    emit(f"simulate_fused_W{windows}_N{n}", us_f,
         f"speedup_vs_segment_loop={us_l/us_f:.2f}x")
    emit(f"segment_loop_W{windows}_N{n}", us_l, "legacy-path")


def _sweep_total_accept(state):
    return state.total_accept


def bench_sweep(quick=False, json_path="BENCH_sweep.json"):
    """Sweep-engine acceptance bench: an 8-seed x 6-config Psi grid at
    N=25 run (a) as ONE `simulate_sweep` device call and (b) as the
    per-cell Python loop it replaces (`simulate` per (config, seed) —
    which recompiles per config, since every distinct `DracoConfig` is a
    fresh static jit key). Wall clock is end-to-end *including*
    compilation — exactly the cost a fig3/fig4 grid run pays — plus
    steady-state (pre-compiled) timings for the dispatch-only view.

    The per-cell math is identical FLOPs on both paths, so the task is
    deliberately small (25 clients, ~3k-param MLP): what this bench
    isolates is the *grid driver* — 1 compile + 1 dispatch vs 6 compiles
    + 48 dispatch/sync round-trips. (At the full ~146k-param fig3 model
    the same grid is compute-bound and the sweep's edge shrinks to the
    batching gain, ~1.3x end-to-end on CPU — see EXPERIMENTS.md.)
    Writes BENCH_sweep.json; the PR-4 acceptance bar is >= 2x end-to-end
    on CPU."""
    import json as json_lib
    import time

    from repro.api import make_context, simulate, simulate_sweep
    from repro.core.channel import ChannelConfig
    from repro.core.protocol import DracoConfig
    from repro.data.synthetic import federated_classification, make_mlp

    n, seeds = 25, 8
    psis = (1, 2, 4, 8, 16, 24)
    windows = 8 if quick else 24
    every = 4 if quick else 8
    key = jax.random.PRNGKey(0)
    k1, k2, key = jax.random.split(key, 3)
    train, test = federated_classification(k1, n, input_dim=16,
                                           num_classes=5, per_client=64)
    params0, _, loss, acc = make_mlp(k2, 16, (32,), 5)
    cfg0 = DracoConfig(num_clients=n, lr=0.05, local_batches=1, batch_size=16,
                       lambda_grad=0.3, lambda_tx=0.3, unify_period=50,
                       topology="cycle", max_delay_windows=4,
                       channel=ChannelConfig(message_bytes=13_000,
                                             gamma_max=10.0))
    grid = [cfg0.replace(psi=int(p)) for p in psis]
    keys = jax.random.split(key, seeds)
    ctx = make_context(grid[0], loss, train, params0=params0)

    def sweep_once():
        _, trace = simulate_sweep(
            "draco", grid, params0, loss, train, windows, keys=keys,
            eval_every=every, eval_fn=acc, eval_data=test, ctx=ctx,
            final_fn=_sweep_total_accept)
        return trace  # numpy: already blocked on device results

    def loop_once():
        out = []
        for cfg in grid:
            ctx_g = ctx.replace(cfg=cfg)
            for k in keys:
                _, tr = simulate("draco", cfg, params0, loss, train, windows,
                                 key=k, eval_every=every, eval_fn=acc,
                                 eval_data=test, ctx=ctx_g)
                out.append(tr.metrics["accuracy"])
        return out

    t0 = time.perf_counter()
    sweep_once()
    sweep_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    loop_once()
    loop_s = time.perf_counter() - t0
    # steady state: both paths now hit their jit caches
    sweep_steady = time_fn(sweep_once, warmup=0, iters=2) / 1e6
    loop_steady = time_fn(loop_once, warmup=0, iters=2) / 1e6

    emit(f"sweep_grid_{seeds}x{len(psis)}_N{n}_W{windows}", sweep_s * 1e6,
         f"end2end_speedup_vs_loop={loop_s / sweep_s:.2f}x")
    emit(f"sweep_loop_{seeds}x{len(psis)}_N{n}_W{windows}", loop_s * 1e6,
         "python-loop-path")
    emit(f"sweep_grid_steady_{seeds}x{len(psis)}_N{n}", sweep_steady * 1e6,
         f"steady_speedup_vs_loop={loop_steady / sweep_steady:.2f}x")
    if json_path:
        with open(json_path, "w") as f:
            json_lib.dump({
                "grid": f"{seeds}seeds_x_{len(psis)}configs",
                "num_clients": n, "windows": windows, "eval_every": every,
                "sweep_s": sweep_s, "loop_s": loop_s,
                "speedup": loop_s / sweep_s,
                "sweep_steady_s": sweep_steady, "loop_steady_s": loop_steady,
                "steady_speedup": loop_steady / sweep_steady,
            }, f, indent=1, sort_keys=True)
        print(f"# wrote {json_path}")


def bench_tasks(quick=False, json_path="BENCH_tasks.json"):
    """Task-layer: per-task DRACO step time at the paper scale (N=25)
    through `simulate(task=...)` — the whole zoo (linear-softmax / mlp /
    small-cnn / tiny-lm) plus one stateful-optimizer row (mlp + adamw:
    the flat (N, 2*Dflat) optimizer plane riding the scan carry).
    Writes BENCH_tasks.json (CI artifact) so per-workload step cost is
    tracked across PRs like the gossip/scenario/sweep benches."""
    import json as json_lib

    from repro.api import simulate
    from repro.core.protocol import DracoConfig
    from repro.tasks import get_task, list_tasks

    n = 8 if quick else 25
    windows = 6 if quick else 12
    iters = 2 if quick else 5
    cfg = DracoConfig(num_clients=n, lr=0.05, local_batches=1, batch_size=16,
                      lambda_grad=0.3, lambda_tx=0.3, unify_period=50,
                      topology="cycle", max_delay_windows=4)
    key = jax.random.PRNGKey(0)
    rows = {}
    variants = [(name, "sgd") for name in list_tasks()] + [("mlp", "adamw")]
    for name, opt in variants:
        task = get_task(name, optimizer=opt)

        def one_run():
            st, _ = simulate("draco", cfg, task=task, num_steps=windows,
                             key=key)
            return st.window_idx

        us = time_fn(one_run, warmup=1, iters=iters) / windows
        tag = f"task_{name}" + (f"_{opt}" if opt != "sgd" else "")
        emit(f"{tag}_draco_window_N{n}", us,
             f"grad_cost={task.grad_cost:.3g}MFLOP")
        rows[f"{tag}_us_per_window"] = us
    if json_path:
        rows.update({"num_clients": n, "windows": windows})
        with open(json_path, "w") as f:
            json_lib.dump(rows, f, indent=1, sort_keys=True)
        print(f"# wrote {json_path} ({len(rows)} entries)")


def bench_fig3(quick=False):
    """Fig. 3 (both panels): DRACO vs baselines final accuracy."""
    from benchmarks.fig3_convergence import run

    for task in (("emnist",) if quick else ("emnist", "poker")):
        curves = run(task, segments=3 if quick else 6,
                     seg_windows=60 if quick else 100,
                     seg_rounds=20 if quick else 30,
                     num_clients=10 if quick else 25)
        draco = curves["draco"][-1]
        best_base = max(c[-1] for m, c in curves.items() if m != "draco")
        emit(f"fig3_{task}_draco_final_acc", 0.0,
             f"draco={draco:.3f}_bestbase={best_base:.3f}")


def bench_fig4(quick=False):
    """Fig. 4: Psi sweep — accuracy and oscillation vs message cap."""
    from benchmarks.fig4_psi_sweep import run

    res = run("emnist", psis=(1, 4, 24) if quick else (1, 2, 4, 8, 24),
              windows=240 if quick else 600,
              num_clients=10 if quick else 25)
    best_psi = max(res, key=lambda p: res[p]["final_acc"])
    emit("fig4_best_psi", 0.0, f"psi={best_psi}_acc={res[best_psi]['final_acc']:.3f}")


def bench_fig_dynamic(quick=False):
    """Scenario engine: accuracy/consensus vs topology churn and
    straggler fraction (writes BENCH_scenarios.json for the CI artifact)."""
    from benchmarks.fig_dynamic import run

    res = run("emnist", quick=quick)
    frozen = res["churn"][0.0]["final_acc"]
    worst_churn = min(r["final_acc"] for r in res["churn"].values())
    worst_strag = min(r["final_acc"] for r in res["straggler"].values())
    emit("fig_dynamic_churn_robustness", 0.0,
         f"frozen={frozen:.3f}_worstchurn={worst_churn:.3f}")
    emit("fig_dynamic_straggler_robustness", 0.0,
         f"worstfrac={worst_strag:.3f}")


def bench_events(quick=False, json_path="BENCH_events.json"):
    """Event engine: per-event dispatch cost vs the windowed engine's
    per-window cost at the paper scale (N=25), plus the staleness-damped
    variant. One tape row does strictly less work than one window (one
    client acts, not a Poisson thinning of all N), but there are ~N x
    (lambda_grad + lambda_tx) x window more rows per simulated second —
    BENCH_events.json records both unit costs and the resulting
    us-per-simulated-second ratio so the speed/fidelity trade is tracked
    across PRs like the other BENCH_* artifacts."""
    import json as json_lib

    from repro.api import simulate
    from repro.events import EventConfig, events_context, simulate_events
    from repro.tasks import get_task

    n = 8 if quick else 25
    horizon = 4.0 if quick else 10.0
    iters = 2 if quick else 5
    cfg = EventConfig(num_clients=n, lr=0.05, local_batches=1, batch_size=16,
                      lambda_grad=0.3, lambda_tx=0.3, unify_period=50,
                      topology="cycle", max_delay_windows=4,
                      staleness="poly")
    task = get_task("linear-softmax")
    key = jax.random.PRNGKey(0)
    data, _ = task.make_data(jax.random.PRNGKey(1), n)
    ctx = events_context(cfg, task=task, data=data, horizon=horizon,
                         params0=task.init_params(key))
    n_events = max(ctx.tape.num_valid, 1)
    rows = {}

    def windowed():
        st, _ = simulate("draco", cfg, task=task, data=data,
                         num_steps=int(horizon / cfg.window), key=key)
        return st.window_idx

    us_w = time_fn(windowed, warmup=1, iters=iters) / (horizon / cfg.window)
    emit(f"draco_window_N{n}", us_w, "us_per_window")
    rows["draco_us_per_window"] = us_w

    for algo in ("draco-event", "fedasync-gossip"):

        def run(algo=algo):
            st, _ = simulate_events(algo, cfg, ctx=ctx, key=key)
            return st.event_idx

        us_e = time_fn(run, warmup=1, iters=iters) / n_events
        emit(f"{algo}_N{n}", us_e, "us_per_event")
        rows[f"{algo.replace('-', '_')}_us_per_event"] = us_e
        rows[f"{algo.replace('-', '_')}_us_per_sim_s"] = (
            us_e * n_events / horizon)
    rows["draco_us_per_sim_s"] = us_w / cfg.window
    rows.update({"num_clients": n, "horizon_s": horizon,
                 "tape_events": n_events,
                 "tape_capacity": ctx.tape.capacity})
    if json_path:
        with open(json_path, "w") as f:
            json_lib.dump(rows, f, indent=1, sort_keys=True)
        print(f"# wrote {json_path} ({len(rows)} entries)")


def bench_decode(quick=False):
    """Serving-layer: single-token decode latency, reduced dense arch."""
    from repro.configs.base import get_reduced
    from repro.models import model as M

    cfg = get_reduced("qwen2-1.5b")
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B = 4
    state = M.init_decode_state(cfg, B, 128)
    tok = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, t, s: M.decode_step(p, cfg, t, s))
    logits, state = step(params, tok, state)  # warm
    us = time_fn(step, params, tok, state, iters=10)
    emit("decode_step_reduced_qwen2", us, f"{B/us*1e6:.0f}tok_s")


BENCHES = {
    "gossip": bench_gossip_mix,
    "ssd": bench_ssd,
    "draco_window": bench_draco_window,
    "simulate_fused": bench_simulate_fused,
    "sweep": bench_sweep,
    "tasks": bench_tasks,
    "events": bench_events,
    "fig3": bench_fig3,
    "fig4": bench_fig4,
    "fig_dynamic": bench_fig_dynamic,
    "decode": bench_decode,
}


def main() -> None:
    from benchmarks.common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    ap.add_argument("--json", default="BENCH_gossip.json",
                    help="machine-readable results path ('' to skip)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        fn(quick=args.quick)
    # a partial (--only) run must not clobber the tracked full-results
    # file; write it only for full sweeps or an explicit --json override
    if args.json and not (args.only and args.json == "BENCH_gossip.json"):
        write_json(args.json)


if __name__ == "__main__":
    main()
